"""Batched serving engine: continuous batching over fixed decode slots.

Real-engine mechanics in miniature:
  * a fixed number of cache lanes (slots) so the jitted decode step never
    recompiles mid-serve;
  * per-lane positions — lanes run at different sequence offsets;
  * admission resets a lane's cache region and streams the prompt through the
    shared decode step one token per engine tick (piggy-backed prefill), so
    new requests join without stalling in-flight generations;
  * finished requests free their lane immediately (continuous batching).

Batched prompt ingestion for throughput-oriented serving is the separate
``prefill`` path (``launch/serve.py``); this engine optimizes latency under a
rolling request mix.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 = greedy


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


@dataclasses.dataclass
class _Lane:
    req: Optional[Request] = None
    prompt_cursor: int = 0        # next prompt token to feed
    generated: Optional[List[int]] = None

    @property
    def prefilling(self) -> bool:
        return self.req is not None and self.prompt_cursor < len(self.req.prompt)


class ServeEngine:
    """Fixed-slot continuous-batching engine (single host, jit-stable)."""

    def __init__(self, params: Any, cfg: ModelConfig, slots: int,
                 cache_len: int, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.key = jax.random.PRNGKey(seed)
        self.state = model.init_decode_state(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)
        self.lanes = [_Lane() for _ in range(slots)]
        self.next_token = np.zeros(slots, np.int32)
        self.steps = 0

        self._decode = jax.jit(
            lambda state, toks, pos: model.decode_step(
                params, cfg, state, {"tokens": toks}, pos
            )
        )

    # -- lane management ----------------------------------------------------

    def _reset_lane(self, i: int) -> None:
        """Zero one lane's cache/state (leaves have layout (cycles, B, ...))."""
        self.state = jax.tree.map(
            lambda x: x.at[:, i].set(jnp.zeros_like(x[:, i])), self.state
        )
        self.pos[i] = 0

    def _admit(self, req: Request) -> bool:
        for i, lane in enumerate(self.lanes):
            if lane.req is None:
                self._reset_lane(i)
                self.lanes[i] = _Lane(req=req, prompt_cursor=0, generated=[])
                self.next_token[i] = int(req.prompt[0])
                return True
        return False

    def _sample(self, logits: Array, temperature: float) -> int:
        if temperature <= 0.0:
            return int(jnp.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, logits / temperature))

    # -- main loop ----------------------------------------------------------

    def run(self, requests: List[Request], max_steps: int = 100_000
            ) -> List[Completion]:
        queue = list(requests)
        done: List[Completion] = []
        while (queue or any(l.req for l in self.lanes)) and \
                self.steps < max_steps:
            while queue and self._admit(queue[0]):
                queue.pop(0)
            if not any(l.req for l in self.lanes):
                continue

            logits, self.state = self._decode(
                self.state, jnp.asarray(self.next_token), jnp.asarray(self.pos)
            )
            self.steps += 1

            for i, lane in enumerate(self.lanes):
                if lane.req is None:
                    continue  # idle lane decoded a dummy token; state unused
                self.pos[i] += 1
                if lane.prefilling:
                    lane.prompt_cursor += 1
                    if lane.prompt_cursor < len(lane.req.prompt):
                        self.next_token[i] = int(lane.req.prompt[lane.prompt_cursor])
                        continue
                # generation phase: sample from this lane's logits
                nxt = self._sample(logits[i], lane.req.temperature)
                lane.generated.append(nxt)
                self.next_token[i] = nxt
                if len(lane.generated) >= lane.req.max_new_tokens or \
                        self.pos[i] >= self.cache_len - 1:
                    done.append(Completion(lane.req.rid, lane.generated))
                    self.lanes[i] = _Lane()
        return done
