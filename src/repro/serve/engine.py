"""Batched serving engine: continuous batching over fixed decode slots.

Real-engine mechanics in miniature:
  * a fixed number of cache lanes (slots) so the jitted decode step never
    recompiles mid-serve;
  * per-lane positions — lanes run at different sequence offsets;
  * admission resets a lane's cache region and streams the prompt through the
    shared decode step one token per engine tick (piggy-backed prefill), so
    new requests join without stalling in-flight generations;
  * finished requests free their lane immediately (continuous batching);
  * optional activation taps: with a ``TapConfig`` the decode step also
    emits per-layer pooled hidden states + a probe target per lane, handed
    to a ``tap_sink`` (normally a ``TelemetryBridge``) each step. Sampled
    tokens are bit-identical with taps on or off — the taps are pure copies
    of values the untapped program already computes (DESIGN.md §14).

Batched prompt ingestion for throughput-oriented serving is the separate
``prefill`` path (``launch/serve.py``); this engine optimizes latency under a
rolling request mix.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig
from repro.telemetry.taps import TapBatch, TapConfig, tapped_decode_fn

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 = greedy


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


@dataclasses.dataclass
class _Lane:
    req: Optional[Request] = None
    prompt_cursor: int = 0        # next prompt token to feed
    generated: Optional[List[int]] = None

    @property
    def prefilling(self) -> bool:
        return self.req is not None and self.prompt_cursor < len(self.req.prompt)


class ServeEngine:
    """Fixed-slot continuous-batching engine (single host, jit-stable)."""

    def __init__(self, params: Any, cfg: ModelConfig, slots: int,
                 cache_len: int, seed: int = 0,
                 taps: Optional[TapConfig] = None,
                 tap_sink: Optional[Callable[[TapBatch], None]] = None):
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.key = jax.random.PRNGKey(seed)
        self.state = model.init_decode_state(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)
        self.lanes = [_Lane() for _ in range(slots)]
        self.next_token = np.zeros(slots, np.int32)
        self.steps = 0
        self.taps = taps
        self.tap_sink = tap_sink

        if taps is not None:
            self._decode = tapped_decode_fn(params, cfg, taps)
        else:
            self._decode = jax.jit(
                lambda state, toks, pos: model.decode_step(
                    params, cfg, state, {"tokens": toks}, pos
                )
            )

        # ONE cached lane-reset program for all lanes: the lane index is a
        # traced operand (jit specializes on shape/dtype, not value), so
        # admission churn across any lane mix reuses a single trace instead
        # of rebuilding the tree-map graph per admission. ``_reset_traces``
        # counts trace events (the Python side effect runs only on cache
        # miss) — pinned to 1 under churny traffic in tests.
        self._reset_traces = 0

        def _reset(state, i):
            self._reset_traces += 1
            return jax.tree.map(
                lambda x: x.at[:, i].set(jnp.zeros((), x.dtype)), state
            )

        self._lane_reset = jax.jit(_reset)

    # -- lane management ----------------------------------------------------

    def _reset_lane(self, i: int) -> None:
        """Zero one lane's cache/state (leaves have layout (cycles, B, ...))."""
        self.state = self._lane_reset(self.state, np.int32(i))
        self.pos[i] = 0

    def _admit(self, req: Request) -> bool:
        """Seat ``req`` in a free lane; False if all lanes are busy.

        Admission is head-of-line: ``run`` admits strictly in queue order
        and stops at the first request that doesn't fit, so a burst never
        reorders around a waiting request. The seated lane is primed with
        ``prompt[0]`` — requests are validated non-empty at submission
        (``run``), so the priming read cannot fail here.
        """
        for i, lane in enumerate(self.lanes):
            if lane.req is None:
                self._reset_lane(i)
                self.lanes[i] = _Lane(req=req, prompt_cursor=0, generated=[])
                self.next_token[i] = int(req.prompt[0])
                return True
        return False

    def _sample(self, logits: Array, temperature: float) -> int:
        if temperature <= 0.0:
            return int(jnp.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, logits / temperature))

    def _emit_taps(self, feats: Array, targets: Array) -> None:
        """Hand one step's taps to the sink with the CURRENT active-lane
        mask — called before lane bookkeeping frees finished lanes, so the
        mask matches the lanes whose features were just computed. Prefill
        steps tap too: prompt tokens are served activations like any other
        (the probe target is the model's next-token view of the prompt)."""
        active = np.array([l.req is not None for l in self.lanes], bool)
        if not active.any():
            return
        self.tap_sink(TapBatch(
            model=self.taps.model, step=self.steps,
            feats=np.asarray(feats), targets=np.asarray(targets),
            mask=active,
        ))

    # -- main loop ----------------------------------------------------------

    def run(self, requests: List[Request], max_steps: int = 100_000
            ) -> List[Completion]:
        for req in requests:
            if len(req.prompt) == 0:
                raise ValueError(
                    f"request {req.rid}: empty prompt — admission primes a "
                    f"lane with prompt[0], so every request needs at least "
                    f"one token"
                )
        queue = list(requests)
        done: List[Completion] = []
        tapped = self.taps is not None
        while (queue or any(l.req for l in self.lanes)) and \
                self.steps < max_steps:
            while queue and self._admit(queue[0]):
                queue.pop(0)
            if not any(l.req for l in self.lanes):
                continue

            if tapped:
                logits, self.state, feats, targets = self._decode(
                    self.state, jnp.asarray(self.next_token),
                    jnp.asarray(self.pos)
                )
            else:
                logits, self.state = self._decode(
                    self.state, jnp.asarray(self.next_token),
                    jnp.asarray(self.pos)
                )
            # Complete the step before the host reads/mutates anything.
            # Generation steps sync through the argmax scalar anyway, but
            # prefill-only steps used to dispatch with NO host sync — and
            # unbounded async depth trips a jaxlib-0.4.36 CPU thunk-runtime
            # race that corrupts decode state under load (first-run token
            # streams diverged from reruns; pinned deterministic in
            # tests/test_serve_engine.py). One step of lookahead is this
            # engine's whole pipeline, so the sync costs nothing real.
            logits.block_until_ready()
            self.steps += 1
            if tapped and self.tap_sink is not None:
                self._emit_taps(feats, targets)

            for i, lane in enumerate(self.lanes):
                if lane.req is None:
                    continue  # idle lane decoded a dummy token; state unused
                self.pos[i] += 1
                if lane.prefilling:
                    lane.prompt_cursor += 1
                    if lane.prompt_cursor < len(lane.req.prompt):
                        self.next_token[i] = int(lane.req.prompt[lane.prompt_cursor])
                        continue
                # generation phase: sample from this lane's logits
                nxt = self._sample(logits[i], lane.req.temperature)
                lane.generated.append(nxt)
                self.next_token[i] = nxt
                if len(lane.generated) >= lane.req.max_new_tokens or \
                        self.pos[i] >= self.cache_len - 1:
                    done.append(Completion(lane.req.rid, lane.generated))
                    self.lanes[i] = _Lane()
        return done
