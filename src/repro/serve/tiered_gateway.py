"""Tiered serving gateway: hot/cold tenant store around the fused tick.

:class:`TieredStormGateway` serves ``num_tenants`` GLOBAL tenants through a
:class:`~repro.serve.storm_gateway.StormGateway` whose bank holds only
``hot_capacity`` resident slots (DESIGN.md §12). The inner gateway is
untouched — it packs each tick against the resident bank only — and this
layer owns the tenant⇄slot indirection plus a
:class:`~repro.core.tiered.TieredBank` for everyone who doesn't fit:

* **Resident traffic** forwards immediately, remapped ``tenant -> slot``;
  completions are rewritten back to global ids via the rid table, so
  clients never see slots.
* **Cold traffic** parks in a FIFO side queue and enqueues a promotion.
  Promotions are scheduled at ``tick_start`` time, AFTER the tick's
  programs dispatch: the slot swap is one jitted program chained (through
  jax async dispatch) on the in-flight tick's output counters, so the
  device overlaps it with nothing blocked host-side, the evicted table
  comes back as futures flushed at ``tick_finish`` (the loop's one sync
  point), and the residency map advances immediately — the promoted
  tenant's queued requests drain into the inner gateway and pack into the
  very NEXT tick.
* **Victim policy** is pluggable (``score_fn`` — ``tiered.TenantStats ->
  priority``, lowest evicts first; default LRU-by-tick) with protection:
  a tenant with queued unpacked traffic in the inner gateway is never
  evicted (its packed in-flight traffic is safe regardless — the swap
  orders after the tick program that read the slot).
* **Fit requests** address global tenants and read through ``sketch_of``
  (hot slot or exact cold copy), so a cohort can mix residencies without
  promoting anyone; they drain at ``tick_finish`` after evictions land.

Never-recompiles contract: the inner gateway's three tick programs plus the
bank's one swap program — ``trace_count <= 4`` for the gateway's lifetime
under any hot/cold request mix (pinned in tests/test_tiered_gateway.py);
``<= 5`` with a finite :class:`~repro.core.privacy.ReleasePolicy`, whose
single extra program (the inner gateway's privatize-on-read query) is the
only addition. Privacy is GLOBAL-tenant-scoped here: one shared ledger/view
keyed by global tenant id backs the inner gateway, so budgets, release
windows, and refusals follow tenants across promote/demote (DESIGN.md §15).

Bit-identity contract: with ``hot_capacity >= num_tenants`` the slot map is
the identity and no swap ever runs — every tick is byte-for-byte the PR-6
resident gateway's tick. With eviction in play, a tenant's sketch after any
promote/demote history equals its always-resident counterpart bit-for-bit
(the swap is a pure slice/update and the cold store is an exact host copy).

The wire front-end (:class:`~repro.serve.wire.StormWireServer`) drives this
class unchanged — it duck-types ``submit`` / ``pending`` / ``tick_start`` /
``tick_finish`` / ``queue_stats``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import (losses, lsh, privacy as privacy_lib,
                        sketch as sketch_lib)
from repro.core.tiered import TieredBank
from repro.serve.storm_gateway import (
    Backpressure,
    FitRequest,
    FitResult,
    IngestRequest,
    InflightTick,
    QueryRequest,
    QueryResult,
    StormGateway,
    TickBudgetExceeded,
    TickReport,
    run_fit_request,
)


class TieredStormGateway:
    """Fixed-tick gateway over a tiered (hot/cold) tenant store."""

    def __init__(
        self,
        params: lsh.LSHParams,
        num_tenants: int,
        hot_capacity: int,
        *,
        paired: bool = True,
        query_slots: int = 32,
        ingest_slots: int = 128,
        count_dtype=jnp.int16,
        mode: str = "auto",
        mesh=None,
        axis: str = "bank",
        max_pending_rows: Optional[int] = None,
        max_pending_points: Optional[int] = None,
        promote_per_tick: int = 2,
        score_fn=None,
        privacy: Optional[privacy_lib.ReleasePolicy] = None,
        privacy_seed: int = 0,
    ):
        """Args mirror :class:`StormGateway` plus the tier knobs:

          num_tenants: global tenant count T (requests address these ids).
          hot_capacity: resident slots H — the inner gateway's bank size
            and the ONLY device-side counter footprint. ``H >= T`` makes
            the tier a transparent wrapper (the bit-identity baseline).
          count_dtype: resident counter dtype — int16/int8 shrink both the
            bank and the per-tick kernel tiles (DESIGN.md §12).
          promote_per_tick: max cold tenants promoted per tick (each is one
            dispatch of the single swap program).
          score_fn: pluggable eviction priority (``tiered.TenantStats ->
            comparable``; lowest evicts first). ``None`` keeps the
            LRU-by-tick default.
          privacy: optional :class:`~repro.core.privacy.ReleasePolicy`.
            The budget is GLOBAL per tenant: one shared
            :class:`~repro.core.privacy.PrivateBankView` backs the inner
            gateway (keyed slot -> global tenant), so eps accounting and
            release windows follow a tenant across promote/demote. A
            demoted tenant's stale lane is dropped (the slot is reused) —
            its cached window survives, so re-promotion at an unchanged
            counter version rebuilds the SAME release free of charge.
          privacy_seed: PRNG seed of the release noise stream.
        """
        if num_tenants < 1:
            raise ValueError(f"need at least one tenant; got {num_tenants}")
        self.num_tenants = num_tenants
        self.tiers = TieredBank(
            num_tenants=num_tenants,
            hot_capacity=hot_capacity,
            rows=params.rows,
            buckets=params.buckets,
            dtype=count_dtype,
            score_fn=score_fn,
        )
        self.privacy = privacy
        self._private = privacy is not None and not privacy.noiseless
        self.private_view = (privacy_lib.PrivateBankView(
            privacy, seed=privacy_seed) if self._private else None)
        counts, n = self.tiers.init_resident()
        self.gw = StormGateway(
            params,
            self.tiers.hot_capacity,
            paired=paired,
            query_slots=query_slots,
            ingest_slots=ingest_slots,
            mode=mode,
            bank=sketch_lib.SketchBank(counts=counts, n=n),
            mesh=mesh,
            axis=axis,
            # Caps are enforced HERE, per global tenant: the inner queues
            # only ever hold traffic this layer already admitted.
            max_pending_rows=None,
            max_pending_points=None,
            privacy=privacy,
            privacy_seed=privacy_seed,
            private_view=self.private_view,
            privacy_key_of=self._slot_key,
        )
        self.max_pending_rows = max_pending_rows
        self.max_pending_points = max_pending_points
        self.promote_per_tick = promote_per_tick
        self._cold_q: Deque[Union[IngestRequest, QueryRequest]] = deque()
        self._fit_q: Deque[FitRequest] = deque()
        self._cold_rows = [0] * num_tenants
        self._cold_points = [0] * num_tenants
        self._rid_tenant: Dict[int, int] = {}
        self.fits_run = 0
        self.promotions = 0
        self.demotions = 0
        self.deferred_promotions = 0

    # -- tenant-space accounting --------------------------------------------

    def _slot_key(self, slot: int) -> int:
        """Ledger key of a resident slot: its GLOBAL tenant.

        Budgets and release windows belong to tenants, not slots — keyed
        this way, the shared view's accounting survives any promote/demote
        history. Unoccupied slots (never carrying traffic) map to a
        negative sentinel no real tenant uses.
        """
        tenant = self.tiers.slot_tenant[slot]
        return tenant if tenant is not None else -1 - slot

    def _inner_pending(self, tenant: int) -> tuple:
        """(rows, points) queued-but-unpacked in the inner gateway."""
        slot = self.tiers.slot_of.get(tenant)
        if slot is None:
            return 0, 0
        return self.gw._pending_rows[slot], self.gw._pending_points[slot]

    def _check_cap(self, tenant: int, kind: str, requested: int) -> None:
        rows, points = self._inner_pending(tenant)
        if kind == "ingest":
            pending = self._cold_rows[tenant] + rows
            limit = self.max_pending_rows
        else:
            pending = self._cold_points[tenant] + points
            limit = self.max_pending_points
        if limit is not None and pending + requested > limit:
            raise Backpressure(tenant, kind, pending, requested, limit)

    # -- request plumbing ---------------------------------------------------

    def submit(self, req: Union[IngestRequest, QueryRequest, FitRequest]
               ) -> None:
        if isinstance(req, FitRequest):
            # Fits address GLOBAL tenants and read through ``sketch_of``
            # (hot slot or cold host copy alike), so they never forward to
            # the slot-space inner gateway and never force a promotion.
            cohort = [int(t) for t in req.tenants]
            if not cohort:
                raise ValueError("fit cohort is empty")
            for t in cohort:
                if not 0 <= t < self.num_tenants:
                    raise ValueError(f"fit tenant {t} out of range "
                                     f"[0, {self.num_tenants})")
            spec = losses.get_surrogate(req.surrogate)
            if spec.paired != self.gw.paired:
                raise ValueError(
                    f"surrogate '{spec.name}' insert flavor does not match "
                    f"this gateway (paired={self.gw.paired})")
            self._fit_q.append(dataclasses.replace(req, tenants=cohort))
            return
        if not 0 <= req.tenant < self.num_tenants:
            raise ValueError(f"tenant {req.tenant} out of range "
                             f"[0, {self.num_tenants})")
        if isinstance(req, IngestRequest):
            z = np.asarray(req.z, np.float32)
            size, kind = z.shape[0], "ingest"
        elif isinstance(req, QueryRequest):
            z = np.asarray(req.thetas, np.float32)
            size, kind = z.shape[0], "query"
        else:
            raise TypeError(f"unknown request type {type(req).__name__}")
        self._check_cap(req.tenant, kind, size)
        slot = self.tiers.slot_of.get(req.tenant)
        if slot is not None:
            self._forward(req, slot)
            self.tiers.touch(req.tenant, self.gw.ticks)
        else:
            self._cold_q.append(req)
            if kind == "ingest":
                self._cold_rows[req.tenant] += size
            else:
                self._cold_points[req.tenant] += size

    def _forward(self, req, slot: int) -> None:
        """Hand a request to the inner gateway in slot space.

        The rid table remembers the GLOBAL tenant so finish-time reports
        can be rewritten — the slot a request ran in is an implementation
        detail clients never observe.
        """
        self._rid_tenant[req.rid] = req.tenant
        self.gw.submit(dataclasses.replace(req, tenant=slot))

    def submit_many(self, reqs: Sequence[Union[IngestRequest, QueryRequest,
                                               FitRequest]]) -> None:
        for r in reqs:
            self.submit(r)

    @property
    def pending(self) -> int:
        return self.gw.pending + len(self._cold_q) + len(self._fit_q)

    @property
    def ticks(self) -> int:
        return self.gw.ticks

    # Delegations so drivers (launcher, benches) treat both gateways alike.
    @property
    def tenants(self) -> int:
        return self.num_tenants

    @property
    def params(self):
        return self.gw.params

    @property
    def paired(self) -> bool:
        return self.gw.paired

    @property
    def rows_ingested(self) -> int:
        return self.gw.rows_ingested

    @property
    def points_served(self) -> int:
        return self.gw.points_served

    @property
    def ingest_slots(self) -> int:
        return self.gw.ingest_slots

    @property
    def query_slots(self) -> int:
        return self.gw.query_slots

    @property
    def trace_count(self) -> int:
        """Tick programs + the swap program: must stay <= 4 for life
        (<= 5 with a finite privacy policy — the inner gateway's one
        extra private-query program)."""
        return self.gw.trace_count + self.tiers.trace_count

    # -- promotion scheduling -----------------------------------------------

    def _protected(self) -> set:
        """Tenants whose slots must survive this round of eviction."""
        out = set()
        for tenant, slot in self.tiers.slot_of.items():
            if (self.gw._pending_rows[slot] > 0
                    or self.gw._pending_points[slot] > 0):
                out.add(tenant)
        return out

    def _schedule_promotions(self, tick: int) -> None:
        """Promote up to ``promote_per_tick`` cold tenants with traffic.

        Runs right after the tick's programs dispatched: each swap chains
        on the in-flight tick's output counters, the residency map
        advances now, and the promoted tenant's parked requests drain into
        the inner queues — packed by the NEXT ``tick_start``.
        """
        if not self._cold_q:
            return
        wanted: List[int] = []
        for req in self._cold_q:
            if req.tenant not in wanted and len(wanted) < self.promote_per_tick:
                wanted.append(req.tenant)
        promoted = set()
        for tenant in wanted:
            protect = self._protected() | promoted
            if self.tiers.victim(protect) is None and \
                    self.tiers._free_slot() is None:
                # Every slot is protected — defer, never stall the tick.
                self.deferred_promotions += 1
                continue
            counts, n, victim = self.tiers.promote(
                tenant, self.gw._counts, self.gw._n, tick=tick,
                protect=protect)
            self.gw._counts, self.gw._n = counts, n
            self.promotions += 1
            if victim is not None:
                self.demotions += 1
                if self._private:
                    # The victim's lane is about to be reused — its stale
                    # release is gone from the device. Its window cache
                    # survives (free bit-identical rebuild on return).
                    self.private_view.drop_resident(victim)
            promoted.add(tenant)
        if not promoted:
            return
        remaining: Deque[Union[IngestRequest, QueryRequest]] = deque()
        for req in self._cold_q:
            if req.tenant in promoted:
                if isinstance(req, IngestRequest):
                    self._cold_rows[req.tenant] -= req.z.shape[0]
                else:
                    self._cold_points[req.tenant] -= req.thetas.shape[0]
                self._forward(req, self.tiers.slot_of[req.tenant])
            else:
                remaining.append(req)
        self._cold_q = remaining

    # -- the tick -----------------------------------------------------------

    def tick_start(self) -> InflightTick:
        """Pack resident traffic, dispatch the tick, then overlap promotions.

        Order matters: the inner pack/dispatch goes first so promotion
        swaps chain AFTER the tick's programs on the device — the tick
        reads the pre-swap slots it packed against, and the swap costs no
        tick latency. LRU clocks advance for every tenant the tick packed.
        """
        for tenant, slot in list(self.tiers.slot_of.items()):
            if (self.gw._pending_rows[slot] > 0
                    or self.gw._pending_points[slot] > 0):
                self.tiers.touch(tenant, self.gw.ticks + 1)
        inflight = self.gw.tick_start()
        self._schedule_promotions(inflight.tick)
        return inflight

    def _run_fits(self) -> List[FitResult]:
        """Drain queued cohort fits over the tiered store.

        Each cohort row reads through :meth:`sketch_of` — a resident
        tenant's live slot or a cold tenant's exact host copy — widened to
        int32, so a fit sees the same counters regardless of residency and
        matches the offline ``erm.fit_many`` bit-for-bit. Fits compile
        their own closures; the <=4 trace budget is untouched.
        """
        out: List[FitResult] = []
        while self._fit_q:
            req = self._fit_q.popleft()
            if self._private:
                out.append(self._run_private_fit(req))
            else:
                sketches = [self.sketch_of(t) for t in req.tenants]
                sub = sketch_lib.SketchBank(
                    counts=jnp.stack([s.counts.astype(jnp.int32)
                                      for s in sketches]),
                    n=jnp.stack([jnp.asarray(s.n, jnp.int32)
                                 for s in sketches]),
                )
                out.append(run_fit_request(req, sub, self.gw.params))
            self.fits_run += 1
        return out

    def _run_private_fit(self, req: FitRequest) -> FitResult:
        """Cohort fit from released tables, tier-aware (DESIGN.md §15).

        Reads go through the GLOBAL shared view, so a cohort can mix
        residencies: a fresh release reads the tenant's counters wherever
        they live (hot slot or exact cold copy) and charges the global
        ledger; an exhausted-but-resident tenant serves its stale device
        lane; an exhausted cold tenant has no lane (dropped at demotion)
        and refuses the request deterministically.
        """
        gw = self.gw
        shape = (gw.params.rows, gw.params.buckets)
        tables, ns = [], []
        stale = False
        for tenant in req.tenants:
            plan = self.private_view.plan_read(
                tenant, gw._rows_of[tenant], shape, paired=gw.paired)
            if plan.status == "refuse":
                return gw._refused_fit(req)
            if plan.status == "fresh":
                sk = self.sketch_of(tenant)
                tables.append(jnp.asarray(sk.counts).astype(jnp.float32)
                              + jnp.asarray(plan.noise))
            else:
                # A "stale" plan implies residency (lanes drop on demote).
                stale = True
                tables.append(gw._release_buf[self.tiers.slot_of[tenant]])
            ns.append(plan.n)
        sub = sketch_lib.SketchBank(counts=jnp.stack(tables),
                                    n=jnp.asarray(ns, jnp.int32))
        res = run_fit_request(req, sub, gw.params)
        if stale:
            res.status = "stale"
        return res

    def tick_finish(self, inflight: InflightTick) -> TickReport:
        """Inner finish + rewrite reports to global ids + land evictions.

        Queued fits drain last — after evictions land — so a cohort that
        mixes hot and cold tenants reads fully-settled counters.
        """
        rep = self.gw.tick_finish(inflight)
        for res in rep.results:
            res.tenant = self._rid_tenant.pop(res.rid, res.tenant)
        for done in rep.ingest_done:
            done.tenant = self._rid_tenant.pop(done.rid, done.tenant)
        self.tiers.flush_evictions()
        if self._fit_q:
            rep.fits.extend(self._run_fits())
        return rep

    def tick(self) -> TickReport:
        return self.tick_finish(self.tick_start())

    def run_until_idle(self, max_ticks: int = 10_000, *,
                       pipelined: bool = False,
                       depth: int = 2) -> List[QueryResult]:
        """Tick until idle (cold tenants promote as ticks pass); all results.

        Same drain loop as :meth:`StormGateway.run_until_idle` — the only
        difference is that ``pending`` includes the cold side queue, which
        empties through promotions scheduled tick by tick.
        """
        out: List[QueryResult] = []
        if pipelined:
            inflight: Deque[InflightTick] = deque()
            while self.pending or inflight:
                while self.pending and len(inflight) < depth and \
                        max_ticks > 0:
                    inflight.append(self.tick_start())
                    max_ticks -= 1
                if not inflight:
                    break
                out.extend(self.tick_finish(inflight.popleft()).results)
        else:
            while self.pending and max_ticks > 0:
                out.extend(self.tick().results)
                max_ticks -= 1
        if self.pending:
            raise TickBudgetExceeded(self.pending, out)
        return out

    # -- reads --------------------------------------------------------------

    def sketch_of(self, tenant: int) -> sketch_lib.Sketch:
        """Tenant's sketch wherever it lives (host copy when cold)."""
        return self.tiers.sketch_of(tenant, self.gw._counts, self.gw._n)

    @property
    def resident_bank(self) -> sketch_lib.SketchBank:
        """The device-resident hot bank (slot-major, NOT tenant-major)."""
        return self.gw.bank

    def rollup(self, assignment, num_groups: Optional[int] = None
               ) -> sketch_lib.SketchBank:
        """Cohort roll-up over ALL tenants without promoting anyone."""
        return self.tiers.rollup(assignment, self.gw._counts, self.gw._n,
                                 num_groups=num_groups)

    def queue_stats(self) -> dict:
        """Gateway state in GLOBAL tenant space, plus tier occupancy."""
        inner = self.gw.queue_stats()
        t = self.num_tenants
        depth = [0] * t
        rows = [0] * t
        points = [0] * t
        for slot, tenant in enumerate(self.tiers.slot_tenant):
            if tenant is None:
                continue
            depth[tenant] += inner["pending_depth"][slot]
            rows[tenant] += inner["pending_rows"][slot]
            points[tenant] += inner["pending_points"][slot]
        for req in self._cold_q:
            depth[req.tenant] += 1
        for tenant in range(t):
            rows[tenant] += self._cold_rows[tenant]
            points[tenant] += self._cold_points[tenant]
        tier = self.tiers.stats()
        tier.update(promotions=self.promotions, demotions=self.demotions,
                    deferred_promotions=self.deferred_promotions,
                    cold_queued=len(self._cold_q))
        stats = {
            "tenants": t,
            "ticks": self.gw.ticks,
            "pending_requests": self.pending,
            "pending_depth": depth,
            "pending_rows": rows,
            "pending_points": points,
            "pending_fits": len(self._fit_q),
            "rows_ingested": self.gw.rows_ingested,
            "points_served": self.gw.points_served,
            "fits_run": self.fits_run,
            "trace_count": self.trace_count,
            "tier": tier,
        }
        if self._private:
            stats["privacy"] = dict(self.private_view.summary(),
                                    queries_refused=self.gw.queries_refused,
                                    fits_refused=self.gw.fits_refused)
        return stats
