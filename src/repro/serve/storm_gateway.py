"""STORM serving gateway: one fused banked call per tick (DESIGN.md §10–11).

The sketch — not the data — is what lives at the edge and gets queried
online, so the serving unit is a :class:`~repro.core.sketch.SketchBank`: S
tenants' counter tables behind one endpoint. The gateway micro-batches two
request classes over fixed engine ticks:

* **ingest** — ``(tenant, z-rows)`` appended to that tenant's counters. All
  pending rows coalesce into ONE fused banked antithetic insert per tick
  (``ops.paired_hash_histogram_banked`` over a mask-padded ``(S, I, dim)``
  stack — the grid-over-S kernel on TPU, the vmapped oracle elsewhere).
* **query** — surrogate-loss evaluation of a theta batch (a client fleet's
  candidates) against that tenant's sketch. All pending points coalesce into
  ONE banked ``ops.query_theta_with_weights(bank, ..., sketch_idx)`` call.
* **fit** — train a tenant cohort end-to-end from its served counters: one
  ``erm.fit_many`` over the cohort's live sub-bank, for any registered
  surrogate whose insert flavor matches the gateway's. Fits drain between
  ticks (at ``tick_finish``, post-ingest) and compile their own loss
  closures, so the three-tick-program jit-stability invariant is untouched.

Both halves run inside jitted tick programs over **jit-stable padded
shapes**: per-tenant slot capacities (``ingest_slots`` rows, ``query_slots``
points) fix every buffer shape, masks mark real traffic, and overflow simply
waits for the next tick. A tick dispatches one of exactly three fixed
programs — ingest+query, ingest-only, query-only, matching which halves
carry traffic — so the engine never recompiles under any request mix
(asserted via the jit caches in tests), and a read-heavy tick does not pay
for an empty insert. Within a mixed tick, ingest applies first and queries
read the post-ingest counters (read-your-writes). On the meshless path each
tick ships ONE fused host buffer to the device (four tiny transfers cost
more than the fused query itself at serving shapes).

**Double-buffered serving (DESIGN.md §11).** A tick is two host-visible
stages: :meth:`StormGateway.tick_start` packs pending traffic and dispatches
the fused programs WITHOUT blocking (JAX async dispatch — the returned
counter/estimate arrays are futures), and :meth:`StormGateway.tick_finish`
performs the only D2H readback (the loss estimates) and reports completions.
``tick()`` is exactly ``tick_finish(tick_start())``, so the synchronous loop
is the depth-1 special case and bit-identity of the pipelined loop is by
construction: packing (the only queue mutation) happens at start time in
dispatch order, the device chains tick t+1's programs on tick t's output
arrays, and readback order equals dispatch order. A driver that keeps two
ticks in flight (``run_until_idle(pipelined=True)``, or the wire server's
engine thread) overlaps tick t+1's host packing with tick t's device
execution and pays ``jax.block_until_ready``-equivalent waits only at
result-completion time, never between ticks.

Admission control: optional per-tenant ``max_pending_rows`` /
``max_pending_points`` caps bound the queues — a submit that would exceed a
tenant's cap raises :class:`Backpressure` (the wire front-end turns this
into an explicit retryable response) instead of growing an unbounded deque.
Slot capacity is per-tenant, so one tenant's flood can neither starve
another tenant's tick slots nor, with caps set, its queue memory.

The tenant-major slot layout is deliberately the member-major contract of
banked fleets (``fleet.member_point_idx`` with ``member_map = arange(S)``),
so a mesh splits tenants across devices exactly like
``distributed.fleet_fit_banked`` splits a training bank
(``sharding.specs.gateway_specs``): each device owns its tenants' tables and
exactly those tenants' tick slots — zero per-tick communication.

Correctness contract (pinned in ``tests/test_serve_gateway.py`` and
``tests/test_serve_async.py``): a tenant's counters after any interleaving
of gateway ticks are bit-identical to the standalone ``sketch_dataset``
build of its stream, a tenant's query results are bit-identical to
standalone ``ops.query_theta_with_weights`` calls against its lone sketch,
and the pipelined loop is bit-identical to the synchronous loop — reports,
counters, and result ordering included.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (dfo, erm, fleet, losses, lsh,
                        privacy as privacy_lib, sketch as sketch_lib)
from repro.kernels import ops

Array = jax.Array


class Backpressure(RuntimeError):
    """A submit would exceed a tenant's bounded-queue capacity.

    Explicit backpressure instead of unbounded queue growth: the caller
    (or the wire front-end, which relays this as a retryable error frame)
    should drain completions and resubmit.
    """

    def __init__(self, tenant: int, kind: str, pending: int, requested: int,
                 limit: int):
        super().__init__(
            f"tenant {tenant} {kind} queue full: {pending} pending + "
            f"{requested} requested > cap {limit}"
        )
        self.tenant = tenant
        self.kind = kind  # "ingest" | "query"
        self.pending = pending
        self.requested = requested
        self.limit = limit


class TickBudgetExceeded(RuntimeError):
    """``run_until_idle`` exhausted its tick budget with requests pending.

    Results that DID complete within the budget are attached as
    ``completed`` (and the number of still-queued requests as ``pending``)
    so a caller can salvage partial progress instead of losing every
    already-served answer.
    """

    def __init__(self, pending: int, completed: List["QueryResult"]):
        super().__init__(f"{pending} requests still pending after the tick "
                         f"budget ({len(completed)} results completed)")
        self.pending = pending
        self.completed = completed


@dataclasses.dataclass
class IngestRequest:
    """Append ``z`` rows to a tenant's counters. For a ``paired`` gateway
    these are pre-scaled sketch-space points (``params.dim - 2`` wide; the
    PRP insert augments internally); for a single-sided gateway they are
    pre-augmented points (``params.dim`` wide — the classification
    contract, ``lsh.augment_data`` applied by the client). Rows beyond the
    tick capacity spill to later ticks."""

    rid: int
    tenant: int
    z: np.ndarray


@dataclasses.dataclass
class QueryRequest:
    """Evaluate the sketch loss at ``thetas`` (``(q, dim)`` iterates, e.g. a
    client fleet's candidates) against a tenant's sketch."""

    rid: int
    tenant: int
    thetas: np.ndarray


@dataclasses.dataclass
class FitRequest:
    """Train a tenant cohort from its SERVED counters (the third request
    class, DESIGN.md §13): one ``erm.fit_many`` over the named tenants'
    live sketches, dispatched between ticks.

    ``surrogate`` names a registered :mod:`repro.core.losses` spec whose
    insert flavor must match the gateway's (``spec.paired == gw.paired``) —
    the counters were built by the gateway's insert path, so only
    same-flavor surrogates read them correctly. The fit compiles its own
    loss closures (separate jit caches), so the three-tick-program
    ``trace_count`` invariant is untouched.
    """

    rid: int
    tenants: Sequence[int]          # the cohort, in result-row order
    surrogate: str = "prp_regression"
    seed: int = 0
    restarts: int = 1
    l2: float = 0.0
    steps: int = 100                # DFO steps (serving fits favor short runs)
    num_queries: int = 8
    sigma: float = 0.5
    learning_rate: float = 1.0
    decay: float = 0.995
    refine_steps: Optional[int] = None  # None -> the surrogate's default


@dataclasses.dataclass
class FitResult:
    """Iterate-space cohort fit: row ``i`` is ``tenants[i]``'s model.

    ``status`` is the privacy verdict under a finite
    :class:`~repro.core.privacy.ReleasePolicy`: ``"ok"`` (fresh releases),
    ``"stale"`` (at least one cohort member trained from its last cached
    release), or ``"refused"`` (an exhausted member with no stale release —
    ``theta``/``fleet_losses`` are zero placeholders).
    """

    rid: int
    tenants: List[int]
    theta: np.ndarray         # (S, dim) float32
    fleet_losses: np.ndarray  # (S, F) final sketch-loss per restart member
    status: str = "ok"


@dataclasses.dataclass
class QueryResult:
    """``status``: ``"ok"``, ``"stale"`` (served from the tenant's last
    cached release after budget exhaustion), or ``"refused"`` (exhausted,
    ``losses`` are zeros — the wire relays a terminal ``budget_exceeded``
    frame instead of a result)."""

    rid: int
    tenant: int
    losses: np.ndarray  # (q,) float32, row i for thetas[i]
    status: str = "ok"


@dataclasses.dataclass
class IngestResult:
    """An ingest request's final row reached the counters this tick."""

    rid: int
    tenant: int
    rows: int


@dataclasses.dataclass
class TickReport:
    """What one engine tick did (completed requests only — a split request
    reports once, on the tick that finishes it)."""

    tick: int
    results: List[QueryResult]
    rows_ingested: int
    points_served: int
    ingest_done: List[IngestResult] = dataclasses.field(default_factory=list)
    fits: List[FitResult] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _PendingIngest:
    req: IngestRequest
    cursor: int = 0


@dataclasses.dataclass
class _PendingQuery:
    req: QueryRequest
    cursor: int = 0
    out: Optional[np.ndarray] = None
    status: str = "ok"


@dataclasses.dataclass
class InflightTick:
    """One dispatched-but-unread tick (DESIGN.md §11 stage contract).

    Everything queue-related was resolved at :meth:`StormGateway.tick_start`
    time; ``est`` is the only device future a finish must wait on, and
    ``placements``/``completes``/``ingest_done`` are the host-side
    bookkeeping that turns the readback into :class:`TickReport` entries.
    """

    tick: int
    est: Optional[Array]  # device future of the fused query, or None
    placements: list  # (pending, req_offset, tenant, slot_offset, count)
    completes: List[_PendingQuery]  # finished packing; report at finish
    ingest_done: List[IngestResult]
    rows: int
    points: int


def run_fit_request(req: FitRequest, bank: sketch_lib.SketchBank,
                    params: lsh.LSHParams) -> FitResult:
    """Execute one cohort fit against an int32 sub-bank (row i = tenants[i]).

    Shared by the flat and tiered gateways: the request's knobs map onto
    ONE ``erm.fit_many`` call, so a gateway fit is bit-identical to the
    offline spine fit over the same counters and seed.
    """
    cfg = dfo.DFOConfig(
        steps=req.steps, num_queries=req.num_queries, sigma=req.sigma,
        learning_rate=req.learning_rate, decay=req.decay,
    )
    res = erm.fit_many(
        req.surrogate, bank, params, jax.random.PRNGKey(req.seed),
        dfo_config=cfg, restarts=req.restarts, l2=req.l2,
        refine_steps=req.refine_steps,
    )
    return FitResult(rid=req.rid, tenants=list(req.tenants),
                     theta=np.asarray(res.theta),
                     fleet_losses=np.asarray(res.fleet_losses))


def _jit_cache_size(f) -> Optional[int]:
    """Best-effort read of a jitted function's trace-cache size.

    ``f._cache_size()`` is private jit API and has moved/broken across JAX
    releases; returning ``None`` (instead of raising, or silently returning
    0) routes :attr:`StormGateway.trace_count` to the gateway's own
    trace-event counter so the jit-stability invariant stays ENFORCED
    rather than vacuously skipped.
    """
    try:
        size = f._cache_size()
    except Exception:
        return None
    return size if isinstance(size, int) else None


class StormGateway:
    """Fixed-tick micro-batching gateway over a :class:`SketchBank`."""

    def __init__(
        self,
        params: lsh.LSHParams,
        tenants: int,
        *,
        paired: bool = True,
        query_slots: int = 32,
        ingest_slots: int = 128,
        count_dtype=jnp.int32,
        mode: str = "auto",
        bank: Optional[sketch_lib.SketchBank] = None,
        mesh=None,
        axis: str = "bank",
        max_pending_rows: Optional[int] = None,
        max_pending_points: Optional[int] = None,
        privacy: Optional[privacy_lib.ReleasePolicy] = None,
        privacy_seed: int = 0,
        private_view: Optional[privacy_lib.PrivateBankView] = None,
        privacy_key_of: Optional[Callable[[int], int]] = None,
    ):
        """Args:
          params: the ONE hash family shared by every tenant's sketch.
          tenants: bank size S (fixed for the gateway's lifetime — the
            tick's padded shapes depend on it).
          paired: PRP sketches (regression/probes) vs single-sided
            (classification margin) — sets both the insert kernel and the
            estimator denominator.
          query_slots: per-tenant theta capacity Q per tick.
          ingest_slots: per-tenant row capacity I per tick.
          count_dtype: counter dtype; narrow dtypes widen per tick and
            saturate on the way back (DESIGN.md §6).
          mode: kernel dispatch for both halves (``auto | kernel |
            interpret | ref``).
          bank: optional warm-start counters (shape ``(S, R, B)``); its
            dtype overrides ``count_dtype``.
          mesh / axis: optional device mesh splitting tenants over ``axis``
            (``sharding.specs.gateway_specs``); ``None`` runs the identical
            program unsharded.
          max_pending_rows: per-tenant cap on queued ingest rows; a submit
            that would exceed it raises :class:`Backpressure`. ``None``
            leaves the queue unbounded.
          max_pending_points: per-tenant cap on queued query points;
            ``None`` = unbounded.
          privacy: optional :class:`~repro.core.privacy.ReleasePolicy`.
            ``None`` or a noiseless policy (``epsilon_release = inf``)
            leaves the gateway EXACTLY as before — the private machinery
            (4th tick program, lane buffer, ledger) is not even built, so
            eps=inf is bit-identical by construction. A finite policy makes
            every query tick a privatize-on-read: ONE noisy release per
            (tenant, tick) covers all coalesced queries, charged to the
            per-tenant ledger; exhausted tenants refuse or serve their
            last cached release per ``policy.on_exhaust``.
          privacy_seed: PRNG seed of the release noise stream.
          private_view: inject a shared
            :class:`~repro.core.privacy.PrivateBankView` (the tiered
            gateway shares ONE global view with its inner gateway).
          privacy_key_of: maps a bank slot to its ledger key (identity by
            default; the tiered gateway maps slot -> GLOBAL tenant so
            budgets follow tenants across promote/demote).
        """
        if tenants < 1:
            raise ValueError(f"need at least one tenant; got {tenants}")
        self.params = params
        self.w = ops.from_lsh_params(params)
        self.dim = params.dim - 2  # query iterate dim (theta_tilde rows)
        # Paired ingest takes raw sketch-space rows (augmented internally);
        # single-sided ingest takes pre-augmented rows at params.dim (the
        # classification contract — clients apply lsh.augment_data).
        self.ingest_dim = params.dim - 2 if paired else params.dim
        self.tenants = tenants
        self.paired = paired
        self.query_slots = query_slots
        self.ingest_slots = ingest_slots
        self.mode = mode
        self.mesh = mesh
        self.axis = axis
        self.max_pending_rows = max_pending_rows
        self.max_pending_points = max_pending_points
        if bank is None:
            bank = sketch_lib.SketchBank(
                counts=jnp.zeros((tenants, params.rows, params.buckets),
                                 jnp.dtype(count_dtype)),
                n=jnp.zeros((tenants,), jnp.int32),
            )
        if bank.counts.shape[0] != tenants:
            raise ValueError(
                f"bank holds {bank.counts.shape[0]} sketches for "
                f"{tenants} tenants"
            )
        self.count_dtype = bank.counts.dtype
        self._counts = bank.counts
        self._n = bank.n
        self._ingest_q: Deque[_PendingIngest] = deque()
        self._query_q: Deque[_PendingQuery] = deque()
        self._fit_q: Deque[FitRequest] = deque()
        self._pending_rows = [0] * tenants
        self._pending_points = [0] * tenants
        self.ticks = 0
        self.rows_ingested = 0
        self.points_served = 0
        self.fits_run = 0
        self.queries_refused = 0
        self.fits_refused = 0
        self._trace_events = 0  # fallback trace counter (see trace_count)

        # Privacy layer (DESIGN.md §15). eps=inf / no policy builds NOTHING:
        # the non-private tick programs below are the whole gateway, so the
        # unlimited-budget path is bit-identical to the pre-privacy gateway
        # by construction (there is no zero-noise float path to diverge).
        self.privacy = privacy
        self._private = privacy is not None and not privacy.noiseless
        self._privacy_key_of = privacy_key_of or (lambda slot: slot)
        self.private_view: Optional[privacy_lib.PrivateBankView] = None
        self._tick_query_private = None
        if self._private:
            if mesh is not None:
                raise NotImplementedError(
                    "finite-epsilon privacy is meshless-only for now; "
                    "eps=inf (ReleasePolicy.unlimited() or privacy=None) "
                    "runs on a mesh unchanged")
            self.private_view = (private_view if private_view is not None
                                 else privacy_lib.PrivateBankView(
                                     privacy, seed=privacy_seed))
            # Device-side stale lanes: slot i carries tenant i's last
            # released table so an exhausted tenant can be served its
            # cached release without any host round-trip.
            self._release_buf = jnp.zeros(
                (tenants, params.rows, params.buckets), jnp.float32)
            # Host-tracked counter versions (cumulative packed rows == the
            # device n, exactly — the host packs every row), keyed by the
            # ledger key so versions follow tenants across slot reuse.
            self._rows_of: Dict[int, int] = defaultdict(int)
            init_n = np.asarray(bank.n)
            if init_n.any():  # warm-start bank: seed the version tracker
                for slot in range(tenants):
                    if init_n[slot]:
                        self._rows_of[self._privacy_key_of(slot)] += \
                            int(init_n[slot])

        self._tick_full, self._tick_ingest, self._tick_query = \
            self._build_ticks()
        if self._private:
            self._tick_query_private = self._build_private_tick()

    # -- request plumbing ---------------------------------------------------

    def submit(self, req: Union[IngestRequest, QueryRequest, FitRequest]
               ) -> None:
        if isinstance(req, FitRequest):
            cohort = [int(t) for t in req.tenants]
            if not cohort:
                raise ValueError("fit cohort is empty")
            for t in cohort:
                if not 0 <= t < self.tenants:
                    raise ValueError(f"fit tenant {t} out of range "
                                     f"[0, {self.tenants})")
            spec = losses.get_surrogate(req.surrogate)
            if spec.paired != self.paired:
                flavor = ("paired (PRP)", "single-sided")
                raise ValueError(
                    f"surrogate '{spec.name}' expects "
                    f"{flavor[0] if spec.paired else flavor[1]} counters but "
                    f"this gateway ingests "
                    f"{flavor[0] if self.paired else flavor[1]}"
                )
            self._fit_q.append(dataclasses.replace(req, tenants=cohort))
            return
        if not 0 <= req.tenant < self.tenants:
            raise ValueError(f"tenant {req.tenant} out of range "
                             f"[0, {self.tenants})")
        if isinstance(req, IngestRequest):
            z = np.asarray(req.z, np.float32)
            if z.ndim != 2 or z.shape[1] != self.ingest_dim:
                raise ValueError(
                    f"ingest rows must be (rows, {self.ingest_dim}); got "
                    f"{z.shape}"
                )
            if self.max_pending_rows is not None and (
                    self._pending_rows[req.tenant] + z.shape[0]
                    > self.max_pending_rows):
                raise Backpressure(req.tenant, "ingest",
                                   self._pending_rows[req.tenant],
                                   z.shape[0], self.max_pending_rows)
            self._pending_rows[req.tenant] += z.shape[0]
            self._ingest_q.append(_PendingIngest(dataclasses.replace(req, z=z)))
        elif isinstance(req, QueryRequest):
            th = np.asarray(req.thetas, np.float32)
            if th.ndim != 2 or th.shape[1] != self.dim:
                raise ValueError(f"query thetas must be (q, {self.dim}); "
                                 f"got {th.shape}")
            if self.max_pending_points is not None and (
                    self._pending_points[req.tenant] + th.shape[0]
                    > self.max_pending_points):
                raise Backpressure(req.tenant, "query",
                                   self._pending_points[req.tenant],
                                   th.shape[0], self.max_pending_points)
            self._pending_points[req.tenant] += th.shape[0]
            self._query_q.append(_PendingQuery(
                dataclasses.replace(req, thetas=th),
                out=np.zeros((th.shape[0],), np.float32),
            ))
        else:
            raise TypeError(f"unknown request type {type(req).__name__}")

    def submit_many(self, reqs: Sequence[Union[IngestRequest, QueryRequest,
                                               FitRequest]]) -> None:
        for r in reqs:
            self.submit(r)

    @property
    def pending(self) -> int:
        return len(self._ingest_q) + len(self._query_q) + len(self._fit_q)

    def queue_stats(self) -> dict:
        """Host-side gateway state for monitoring / the wire stats reply.

        ``pending_depth[t]`` is the number of queued REQUESTS for tenant
        ``t`` (ingest + query, split requests still counting once) —
        the row/point tallies alone can't distinguish one giant request
        from a pile of small ones, which is exactly what Backpressure
        tuning needs to see.
        """
        depth = [0] * self.tenants
        for st in self._ingest_q:
            depth[st.req.tenant] += 1
        for st in self._query_q:
            depth[st.req.tenant] += 1
        stats = {
            "tenants": self.tenants,
            "ticks": self.ticks,
            "pending_requests": self.pending,
            "pending_depth": depth,
            "pending_rows": list(self._pending_rows),
            "pending_points": list(self._pending_points),
            "pending_fits": len(self._fit_q),
            "rows_ingested": self.rows_ingested,
            "points_served": self.points_served,
            "fits_run": self.fits_run,
            "trace_count": self.trace_count,
        }
        if self._private:
            stats["privacy"] = dict(self.private_view.summary(),
                                    queries_refused=self.queries_refused,
                                    fits_refused=self.fits_refused)
        return stats

    @property
    def bank(self) -> sketch_lib.SketchBank:
        """The live counter bank (device arrays; post-last-tick state)."""
        return sketch_lib.SketchBank(counts=self._counts, n=self._n)

    def sketch_of(self, tenant: int) -> sketch_lib.Sketch:
        """Tenant ``tenant``'s sketch as a standalone view."""
        return self.bank.select(tenant)

    @property
    def trace_count(self) -> int:
        """Total traces across the fixed tick programs (jit-stability: this
        must stay <= 3 for any request mix over the gateway's lifetime —
        <= 4 with a finite privacy policy, which adds exactly ONE more
        fixed program, the masked noise-add private query).

        Prefers the jit caches (``_cache_size``, private API) and falls back
        to the gateway's own trace-event counter — each tick program bumps
        ``_trace_events`` when (and only when) its Python body is traced —
        so the invariant survives JAX versions that rename the private
        accessor instead of silently reporting zero.
        """
        progs = [self._tick_full, self._tick_ingest, self._tick_query]
        if self._tick_query_private is not None:
            progs.append(self._tick_query_private)
        sizes = [_jit_cache_size(f) for f in progs]
        if any(s is None for s in sizes):
            return self._trace_events
        return sum(sizes)

    # -- the fused tick -----------------------------------------------------

    def _counting(self, fn):
        """Bump the fallback trace counter when ``fn``'s body is traced.

        The increment is a Python side effect, so under ``jax.jit`` it runs
        once per trace (cache miss), never per call — exactly the event
        ``trace_count`` wants when ``_cache_size`` is unavailable.
        """
        def wrapped(*args):
            self._trace_events += 1
            return fn(*args)
        return wrapped

    def _build_ticks(self):
        """Build the three fixed tick programs (full / ingest / query).

        Each is its own jitted program over the same padded shapes — the
        tick picks one by which halves carry traffic, so a read-heavy tick
        never executes an all-masked insert (on these shapes the empty
        paired histogram costs several times the fused query itself).
        """
        w = self.w
        paired = self.paired
        mode = self.mode
        dtype = self.count_dtype
        s, dim, in_dim = self.tenants, self.dim, self.ingest_dim
        i_cap, q_cap = self.ingest_slots, self.query_slots

        def ingest_half(counts, n, zbuf, zmask):
            # ONE fused banked insert over the (S, I, dim) stack. Narrow
            # banks get narrow tiles straight from the kernel (int32 stays
            # in VMEM scratch, one epilogue saturate — DESIGN.md §12) and
            # the saturating carry add; since increments are non-negative,
            # clamp(counts + clamp(tile)) == clamp(counts + tile), so this
            # is bit-identical to the widen-the-whole-bank path it replaces.
            if paired:
                tile = ops.paired_hash_histogram_banked(zbuf, w, zmask,
                                                        mode=mode,
                                                        out_dtype=dtype)
            else:
                tile = ops.hash_histogram_banked(zbuf, w, zmask, mode=mode,
                                                 out_dtype=dtype)
            new_counts = sketch_lib.saturating_add(counts, tile)
            return new_counts, n + jnp.sum(zmask, axis=1).astype(jnp.int32)

        def query_half(counts, n, qbuf, qmask):
            # ONE banked call; tenant-major slots route row i to table
            # i // Q (the member-major contract, member_map = arange(S)).
            idx = fleet.member_point_idx(
                jnp.arange(counts.shape[0], dtype=jnp.int32), qbuf.shape[0]
            )
            est = ops.query_theta_with_weights(
                sketch_lib.SketchBank(counts=counts, n=n),
                w, qbuf, paired=paired, mode=mode, sketch_idx=idx,
            )
            return jnp.where(qmask > 0, est, 0.0)

        def tick_full(counts, n, zbuf, zmask, qbuf, qmask):
            counts, n = ingest_half(counts, n, zbuf, zmask)
            return counts, n, query_half(counts, n, qbuf, qmask)

        def tick_ingest(counts, n, zbuf, zmask):
            return ingest_half(counts, n, zbuf, zmask)

        def tick_query(counts, n, qbuf, qmask):
            return query_half(counts, n, qbuf, qmask)

        if self.mesh is None:
            # Meshless fast path: ONE fused host->device transfer per tick.
            # The flat buffer is [zbuf | zmask | qbuf | qmask] (the suffix a
            # variant doesn't need is simply not shipped); slicing happens
            # inside the compiled program.
            z_end, zm_end = s * i_cap * in_dim, s * i_cap * (in_dim + 1)

            def unpack_ingest(flat):
                return (flat[:z_end].reshape(s, i_cap, in_dim),
                        flat[z_end:zm_end].reshape(s, i_cap))

            def unpack_query(flat, off):
                q_end = off + s * q_cap * dim
                return (flat[off:q_end].reshape(s * q_cap, dim),
                        flat[q_end:q_end + s * q_cap])

            return (
                jax.jit(self._counting(lambda counts, n, flat: tick_full(
                    counts, n, *unpack_ingest(flat),
                    *unpack_query(flat, zm_end)))),
                jax.jit(self._counting(lambda counts, n, flat: tick_ingest(
                    counts, n, *unpack_ingest(flat)))),
                jax.jit(self._counting(lambda counts, n, flat: tick_query(
                    counts, n, *unpack_query(flat, 0)))),
            )

        from repro import compat
        from repro.sharding import specs as sharding_specs

        bank_spec, _ = sharding_specs.gateway_specs(self.axis)
        sharding_specs.check_bank_divisible(self.tenants, self.mesh,
                                            self.axis)
        # Tick buffers get explicit tenant-axis shardings at dispatch time
        # (device_put before the call), so the h2d transfer of tick t+1 can
        # overlap tick t's execution instead of serializing inside the
        # sharded call (DESIGN.md §11 overlap invariant).
        self._in_shardings = sharding_specs.named(
            self.mesh, sharding_specs.gateway_input_specs(self.axis))

        def shard(fn, n_in, n_out):
            return jax.jit(self._counting(compat.shard_map(
                fn, mesh=self.mesh,
                in_specs=(bank_spec,) * n_in,
                out_specs=(bank_spec,) * n_out if n_out > 1 else bank_spec,
            )))

        return (shard(tick_full, 6, 3), shard(tick_ingest, 4, 2),
                shard(tick_query, 4, 1))

    def _build_private_tick(self):
        """The ONE extra fixed program of a finite privacy policy.

        A masked noise-add on the packed query buffer: per slot, either
        rebuild this tick's release (``f32(counts) + noise`` — fresh, or a
        bit-identical free rebuild inside an open window) or carry the
        slot's stale lane, then run the same fused banked query over the
        released f32 tables with the RELEASE-TIME denominators. The lanes
        are an output, so stale serving never needs a host round-trip. The
        flat buffer is ``[qbuf | qmask | noise | fresh]`` (same fused-H2D
        discipline as the other programs); ``n_used`` rides as a tiny int32
        side input to keep release counts exact beyond f32's 2^24.

        The banked query runs in ``mode="ref"`` — the released tables are
        f32 and the reference gather is the path specified for float
        counters (the int-tile Pallas kernels are not); the pure-jnp gather
        fuses fine inside this jitted program.
        """
        w = self.w
        paired = self.paired
        s, dim, q_cap = self.tenants, self.dim, self.query_slots
        r, b = self.params.rows, self.params.buckets

        def tick_query_private(counts, stale, flat, n_used):
            q_end = s * q_cap * dim
            qm_end = q_end + s * q_cap
            nz_end = qm_end + s * r * b
            qbuf = flat[:q_end].reshape(s * q_cap, dim)
            qmask = flat[q_end:qm_end]
            noise = flat[qm_end:nz_end].reshape(s, r, b)
            fresh = flat[nz_end:nz_end + s]
            released = jnp.where(fresh[:, None, None] > 0,
                                 counts.astype(jnp.float32) + noise, stale)
            idx = fleet.member_point_idx(
                jnp.arange(s, dtype=jnp.int32), qbuf.shape[0])
            est = ops.query_theta_with_weights(
                sketch_lib.SketchBank(counts=released, n=n_used),
                w, qbuf, paired=paired, mode="ref", sketch_idx=idx,
            )
            return released, jnp.where(qmask > 0, est, 0.0)

        return jax.jit(self._counting(tick_query_private))

    def _pack_ingest(self):
        s, i_cap, dim = self.tenants, self.ingest_slots, self.ingest_dim
        zbuf = np.zeros((s, i_cap, dim), np.float32)
        zmask = np.zeros((s, i_cap), np.float32)
        fill = [0] * s
        taken = 0
        done: List[IngestResult] = []
        for st in self._ingest_q:
            t = st.req.tenant
            take = min(i_cap - fill[t], st.req.z.shape[0] - st.cursor)
            if take <= 0:
                continue
            zbuf[t, fill[t]:fill[t] + take] = st.req.z[
                st.cursor:st.cursor + take]
            zmask[t, fill[t]:fill[t] + take] = 1.0
            st.cursor += take
            fill[t] += take
            taken += take
            self._pending_rows[t] -= take
        remaining: Deque[_PendingIngest] = deque()
        for st in self._ingest_q:
            if st.cursor < st.req.z.shape[0]:
                remaining.append(st)
            else:
                done.append(IngestResult(st.req.rid, st.req.tenant,
                                         st.req.z.shape[0]))
        self._ingest_q = remaining
        return zbuf, zmask, taken, done

    def _pack_queries(self):
        s, q_cap, dim = self.tenants, self.query_slots, self.dim
        qbuf = np.zeros((s, q_cap, dim), np.float32)
        qmask = np.zeros((s, q_cap), np.float32)
        fill = [0] * s
        placements = []  # (pending, req_offset, tenant, slot_offset, count)
        for st in self._query_q:
            t = st.req.tenant
            take = min(q_cap - fill[t], st.req.thetas.shape[0] - st.cursor)
            if take <= 0:
                continue
            qbuf[t, fill[t]:fill[t] + take] = st.req.thetas[
                st.cursor:st.cursor + take]
            qmask[t, fill[t]:fill[t] + take] = 1.0
            placements.append((st, st.cursor, t, fill[t], take))
            st.cursor += take
            fill[t] += take
            self._pending_points[t] -= take
        # Fully-packed requests leave the queue NOW (dispatch order) and
        # report at finish time — including zero-row requests, which have
        # no rows to place but must still complete (possibly on a tick
        # whose query half is otherwise empty).
        completes: List[_PendingQuery] = []
        remaining: Deque[_PendingQuery] = deque()
        for st in self._query_q:
            if st.cursor == st.req.thetas.shape[0]:
                completes.append(st)
            else:
                remaining.append(st)
        self._query_q = remaining
        return qbuf, qmask, placements, completes

    # -- privatize-on-read planning (finite policy only) --------------------

    def _plan_private_reads(self) -> Dict[int, privacy_lib.ReadPlan]:
        """One ReadPlan per slot that will read counters this tick.

        Exactly the slots with >= 1 queued query point: per-tenant slot
        capacity guarantees each packs at least one point this tick, so
        each needs (at most) one release — the coalescing argument. Slots
        whose queue holds only zero-point requests read nothing and are
        not planned (an empty read must not spend budget). Runs AFTER
        ``_pack_ingest`` so plans see this tick's post-ingest versions
        (the program order: ingest applies first, read-your-writes).
        """
        shape = (self.params.rows, self.params.buckets)
        plans: Dict[int, privacy_lib.ReadPlan] = {}
        for slot in range(self.tenants):
            if self._pending_points[slot] <= 0:
                continue
            key = self._privacy_key_of(slot)
            plans[slot] = self.private_view.plan_read(
                key, self._rows_of[key], shape, paired=self.paired)
        return plans

    def _refuse_queries(self, refused_slots) -> List[_PendingQuery]:
        """Complete every pending query of the refused slots, typed.

        Refusal happens at plan time, BEFORE packing: refused requests
        never occupy tick slots, so other tenants in the same tick are
        untouched. Zero-point requests pass through (they read nothing —
        an exhausted tenant's empty query still completes ``"ok"``).
        """
        if not refused_slots:
            return []
        refused: List[_PendingQuery] = []
        remaining: Deque[_PendingQuery] = deque()
        for st in self._query_q:
            pts_left = st.req.thetas.shape[0] - st.cursor
            if st.req.tenant in refused_slots and pts_left > 0:
                st.status = "refused"
                st.out[st.cursor:] = 0.0
                self._pending_points[st.req.tenant] -= pts_left
                refused.append(st)
            else:
                remaining.append(st)
        self._query_q = remaining
        self.queries_refused += len(refused)
        return refused

    def _private_query_buffers(self, plans):
        """Per-slot (noise, fresh, n_used) arrays for the private program."""
        s = self.tenants
        noise = np.zeros((s, self.params.rows, self.params.buckets),
                         np.float32)
        fresh = np.zeros((s,), np.float32)
        n_used = np.zeros((s,), np.int32)
        for slot, plan in plans.items():
            n_used[slot] = plan.n
            if plan.status == "fresh":
                noise[slot] = plan.noise
                fresh[slot] = 1.0
        return noise, fresh, n_used

    def tick_start(self) -> InflightTick:
        """Pack pending traffic and dispatch the fused tick WITHOUT blocking.

        All queue mutation happens here, synchronously, in dispatch order;
        the returned :class:`InflightTick` carries the device future of the
        loss estimates (``est``) plus the host bookkeeping
        :meth:`tick_finish` needs. The counter/count arrays advance to the
        dispatched programs' outputs immediately — they are futures, and
        the next ``tick_start`` chains on them without a host sync, which
        is what lets a depth-2 driver pack tick t+1 while tick t runs.
        """
        self.ticks += 1
        if not self._ingest_q and not self._query_q:
            # Idle tick: nothing to pack, nothing to run.
            return InflightTick(tick=self.ticks, est=None, placements=[],
                                completes=[], ingest_done=[], rows=0,
                                points=0)
        zbuf, zmask, rows, ingest_done = self._pack_ingest()
        plans: Dict[int, privacy_lib.ReadPlan] = {}
        refused: List[_PendingQuery] = []
        if self._private:
            # Host version tracking: the packed rows ARE this tick's
            # inserts, so versions advance exactly like the device n does.
            if rows:
                per_slot = zmask.sum(axis=1)
                for slot in np.nonzero(per_slot)[0]:
                    self._rows_of[self._privacy_key_of(int(slot))] += \
                        int(per_slot[slot])
            plans = self._plan_private_reads()
            refused = self._refuse_queries(
                {s for s, p in plans.items() if p.status == "refuse"})
        qbuf, qmask, placements, completes = self._pack_queries()
        if refused:
            completes = refused + completes
        for st, _, t, _, _ in placements:
            if t in plans and plans[t].status == "stale":
                st.status = "stale"
        do_ingest, do_query = rows > 0, bool(placements)
        est = None
        if self._private:
            if do_ingest:
                flat = np.concatenate([zbuf.ravel(), zmask.ravel()])
                self._counts, self._n = self._tick_ingest(
                    self._counts, self._n, flat)
            if do_query:
                noise, fresh, n_used = self._private_query_buffers(plans)
                flat = np.concatenate([qbuf.ravel(), qmask.ravel(),
                                       noise.ravel(), fresh])
                self._release_buf, est = self._tick_query_private(
                    self._counts, self._release_buf, flat, n_used)
                for slot, plan in plans.items():
                    if plan.status == "fresh":
                        self.private_view.mark_resident(
                            self._privacy_key_of(slot))
        elif self.mesh is None:
            if do_ingest and do_query:
                flat = np.concatenate([zbuf.ravel(), zmask.ravel(),
                                       qbuf.ravel(), qmask.ravel()])
                self._counts, self._n, est = self._tick_full(
                    self._counts, self._n, flat)
            elif do_ingest:
                flat = np.concatenate([zbuf.ravel(), zmask.ravel()])
                self._counts, self._n = self._tick_ingest(
                    self._counts, self._n, flat)
            elif do_query:
                flat = np.concatenate([qbuf.ravel(), qmask.ravel()])
                est = self._tick_query(self._counts, self._n, flat)
        else:
            sh_z, sh_zm, sh_q, sh_qm = self._in_shardings
            zargs = (jax.device_put(zbuf, sh_z),
                     jax.device_put(zmask, sh_zm))
            qargs = (jax.device_put(qbuf.reshape(-1, self.dim), sh_q),
                     jax.device_put(qmask.reshape(-1), sh_qm))
            if do_ingest and do_query:
                self._counts, self._n, est = self._tick_full(
                    self._counts, self._n, *zargs, *qargs)
            elif do_ingest:
                self._counts, self._n = self._tick_ingest(
                    self._counts, self._n, *zargs)
            elif do_query:
                est = self._tick_query(self._counts, self._n, *qargs)
        points = sum(take for *_, take in placements)
        return InflightTick(tick=self.ticks, est=est, placements=placements,
                            completes=completes, ingest_done=ingest_done,
                            rows=rows, points=points)

    def _run_fits(self) -> List[FitResult]:
        """Drain the fit queue against the POST-tick counters.

        Each request gathers its cohort's live counters into a sub-bank
        (widened to int32 — exact, the training dtype) and runs one
        ``erm.fit_many``: S tenants x F restarts on a single fused banked
        query stream per DFO step. The result is bit-identical to an
        offline ``erm.fit_many`` over the same counters and seed (pinned in
        ``tests/test_serve_fit.py``). Fits jit their own loss closures, so
        the tick programs' trace caches never grow here.
        """
        out: List[FitResult] = []
        while self._fit_q:
            req = self._fit_q.popleft()
            if self._private:
                out.append(self._run_private_fit(req))
            else:
                idx = jnp.asarray(req.tenants, jnp.int32)
                sub = sketch_lib.SketchBank(
                    counts=self._counts[idx].astype(jnp.int32),
                    n=self._n[idx],
                )
                out.append(run_fit_request(req, sub, self.params))
            self.fits_run += 1
        return out

    def _refused_fit(self, req: FitRequest) -> FitResult:
        s = len(req.tenants)
        self.fits_refused += 1
        return FitResult(rid=req.rid, tenants=list(req.tenants),
                         theta=np.zeros((s, self.dim), np.float32),
                         fleet_losses=np.zeros((s, req.restarts), np.float32),
                         status="refused")

    def _run_private_fit(self, req: FitRequest) -> FitResult:
        """Cohort fit from RELEASED tables only (finite policy).

        Each cohort member reads through the shared view: an open window
        rebuilds its cached release for free, a closed one charges a new
        release, an exhausted member serves its stale lane (or refuses the
        whole request — deterministic, nothing trained on partial data).
        The sub-bank is f32 released counters with release-time n, flowing
        through the UNCHANGED ``erm.fit_many`` spine — the query gather
        widens to f32 regardless, so privatized tables train as-is.
        """
        shape = (self.params.rows, self.params.buckets)
        tables, ns = [], []
        stale = False
        for slot in req.tenants:
            key = self._privacy_key_of(slot)
            plan = self.private_view.plan_read(
                key, self._rows_of[key], shape, paired=self.paired)
            if plan.status == "refuse":
                return self._refused_fit(req)
            if plan.status == "fresh":
                tables.append(self._counts[slot].astype(jnp.float32)
                              + jnp.asarray(plan.noise))
            else:
                stale = True
                tables.append(self._release_buf[slot])
            ns.append(plan.n)
        sub = sketch_lib.SketchBank(counts=jnp.stack(tables),
                                    n=jnp.asarray(ns, jnp.int32))
        res = run_fit_request(req, sub, self.params)
        if stale:
            res.status = "stale"
        return res

    def tick_finish(self, inflight: InflightTick) -> TickReport:
        """Read back one dispatched tick's estimates and report completions.

        The ``np.asarray(est)`` here is the ONLY device->host sync in the
        serving loop; with another tick already dispatched it overlaps that
        tick's execution. Finish ticks in dispatch order — results land in
        request ``out`` buffers cumulatively across the ticks of a split
        request. Queued fit requests drain HERE, after the tick's ingest
        has landed — "between ticks" in the stage pipeline, reading the
        freshest served counters.
        """
        results: List[QueryResult] = []
        if inflight.est is not None:
            losses = np.asarray(inflight.est).reshape(self.tenants,
                                                      self.query_slots)
            for st, req_off, t, slot_off, take in inflight.placements:
                st.out[req_off:req_off + take] = \
                    losses[t, slot_off:slot_off + take]
        for st in inflight.completes:
            results.append(QueryResult(st.req.rid, st.req.tenant, st.out,
                                       status=st.status))
        self.rows_ingested += inflight.rows
        self.points_served += inflight.points
        fits = self._run_fits() if self._fit_q else []
        return TickReport(tick=inflight.tick, results=results,
                          rows_ingested=inflight.rows,
                          points_served=inflight.points,
                          ingest_done=inflight.ingest_done,
                          fits=fits)

    def tick(self) -> TickReport:
        """Run one engine tick synchronously: fused banked ingest, then
        fused banked query, then block for the results.

        Exactly ``tick_finish(tick_start())`` — the depth-1 degenerate case
        of the pipelined loop, kept as the simple API and the A/B baseline.
        Dispatches one of the three fixed programs by which halves carry
        traffic; an idle tick is a host-side no-op. Queries packed into a
        mixed tick read the post-ingest counters (read-your-writes).
        """
        return self.tick_finish(self.tick_start())

    def run_until_idle(self, max_ticks: int = 10_000, *,
                       pipelined: bool = False,
                       depth: int = 2) -> List[QueryResult]:
        """Tick until every pending request is served; returns all results.

        ``pipelined=True`` drains with up to ``depth`` ticks in flight
        (double-buffered: pack tick t+1 while tick t runs) — bit-identical
        results and counters, better wall-clock. On budget exhaustion
        raises :class:`TickBudgetExceeded` carrying the results that DID
        complete.
        """
        out: List[QueryResult] = []
        if pipelined:
            inflight: Deque[InflightTick] = deque()
            while self.pending or inflight:
                while self.pending and len(inflight) < depth and \
                        max_ticks > 0:
                    inflight.append(self.tick_start())
                    max_ticks -= 1
                if not inflight:
                    break  # pending traffic but no tick budget left
                out.extend(self.tick_finish(inflight.popleft()).results)
        else:
            while self.pending and max_ticks > 0:
                out.extend(self.tick().results)
                max_ticks -= 1
        if self.pending:
            raise TickBudgetExceeded(self.pending, out)
        return out
