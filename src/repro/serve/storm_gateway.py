"""STORM serving gateway: one fused banked call per tick (DESIGN.md §10).

The sketch — not the data — is what lives at the edge and gets queried
online, so the serving unit is a :class:`~repro.core.sketch.SketchBank`: S
tenants' counter tables behind one endpoint. The gateway micro-batches two
request classes over fixed engine ticks:

* **ingest** — ``(tenant, z-rows)`` appended to that tenant's counters. All
  pending rows coalesce into ONE fused banked antithetic insert per tick
  (``ops.paired_hash_histogram_banked`` over a mask-padded ``(S, I, dim)``
  stack — the grid-over-S kernel on TPU, the vmapped oracle elsewhere).
* **query** — surrogate-loss evaluation of a theta batch (a client fleet's
  candidates) against that tenant's sketch. All pending points coalesce into
  ONE banked ``ops.query_theta_with_weights(bank, ..., sketch_idx)`` call.

Both halves run inside jitted tick programs over **jit-stable padded
shapes**: per-tenant slot capacities (``ingest_slots`` rows, ``query_slots``
points) fix every buffer shape, masks mark real traffic, and overflow simply
waits for the next tick. A tick dispatches one of exactly three fixed
programs — ingest+query, ingest-only, query-only, matching which halves
carry traffic — so the engine never recompiles under any request mix
(asserted via the jit caches in tests), and a read-heavy tick does not pay
for an empty insert. Within a mixed tick, ingest applies first and queries
read the post-ingest counters (read-your-writes). On the meshless path each
tick ships ONE fused host buffer to the device (four tiny transfers cost
more than the fused query itself at serving shapes).

The tenant-major slot layout is deliberately the member-major contract of
banked fleets (``fleet.member_point_idx`` with ``member_map = arange(S)``),
so a mesh splits tenants across devices exactly like
``distributed.fleet_fit_banked`` splits a training bank
(``sharding.specs.gateway_specs``): each device owns its tenants' tables and
exactly those tenants' tick slots — zero per-tick communication.

Correctness contract (pinned in ``tests/test_serve_gateway.py``): a tenant's
counters after any interleaving of gateway ticks are bit-identical to the
standalone ``sketch_dataset`` build of its stream, and a tenant's query
results are bit-identical to standalone ``ops.query_theta_with_weights``
calls against its lone sketch.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fleet, lsh, sketch as sketch_lib
from repro.kernels import ops

Array = jax.Array


@dataclasses.dataclass
class IngestRequest:
    """Append ``z`` rows to a tenant's counters. For a ``paired`` gateway
    these are pre-scaled sketch-space points (``params.dim - 2`` wide; the
    PRP insert augments internally); for a single-sided gateway they are
    pre-augmented points (``params.dim`` wide — the classification
    contract, ``lsh.augment_data`` applied by the client). Rows beyond the
    tick capacity spill to later ticks."""

    rid: int
    tenant: int
    z: np.ndarray


@dataclasses.dataclass
class QueryRequest:
    """Evaluate the sketch loss at ``thetas`` (``(q, dim)`` iterates, e.g. a
    client fleet's candidates) against a tenant's sketch."""

    rid: int
    tenant: int
    thetas: np.ndarray


@dataclasses.dataclass
class QueryResult:
    rid: int
    tenant: int
    losses: np.ndarray  # (q,) float32, row i for thetas[i]


@dataclasses.dataclass
class TickReport:
    """What one engine tick did (completed queries only — a split request
    reports once, on the tick that finishes it)."""

    tick: int
    results: List[QueryResult]
    rows_ingested: int
    points_served: int


@dataclasses.dataclass
class _PendingIngest:
    req: IngestRequest
    cursor: int = 0


@dataclasses.dataclass
class _PendingQuery:
    req: QueryRequest
    cursor: int = 0
    out: Optional[np.ndarray] = None


class StormGateway:
    """Fixed-tick micro-batching gateway over a :class:`SketchBank`."""

    def __init__(
        self,
        params: lsh.LSHParams,
        tenants: int,
        *,
        paired: bool = True,
        query_slots: int = 32,
        ingest_slots: int = 128,
        count_dtype=jnp.int32,
        mode: str = "auto",
        bank: Optional[sketch_lib.SketchBank] = None,
        mesh=None,
        axis: str = "bank",
    ):
        """Args:
          params: the ONE hash family shared by every tenant's sketch.
          tenants: bank size S (fixed for the gateway's lifetime — the
            tick's padded shapes depend on it).
          paired: PRP sketches (regression/probes) vs single-sided
            (classification margin) — sets both the insert kernel and the
            estimator denominator.
          query_slots: per-tenant theta capacity Q per tick.
          ingest_slots: per-tenant row capacity I per tick.
          count_dtype: counter dtype; narrow dtypes widen per tick and
            saturate on the way back (DESIGN.md §6).
          mode: kernel dispatch for both halves (``auto | kernel |
            interpret | ref``).
          bank: optional warm-start counters (shape ``(S, R, B)``); its
            dtype overrides ``count_dtype``.
          mesh / axis: optional device mesh splitting tenants over ``axis``
            (``sharding.specs.gateway_specs``); ``None`` runs the identical
            program unsharded.
        """
        if tenants < 1:
            raise ValueError(f"need at least one tenant; got {tenants}")
        self.params = params
        self.w = ops.from_lsh_params(params)
        self.dim = params.dim - 2  # query iterate dim (theta_tilde rows)
        # Paired ingest takes raw sketch-space rows (augmented internally);
        # single-sided ingest takes pre-augmented rows at params.dim (the
        # classification contract — clients apply lsh.augment_data).
        self.ingest_dim = params.dim - 2 if paired else params.dim
        self.tenants = tenants
        self.paired = paired
        self.query_slots = query_slots
        self.ingest_slots = ingest_slots
        self.mode = mode
        self.mesh = mesh
        self.axis = axis
        if bank is None:
            bank = sketch_lib.SketchBank(
                counts=jnp.zeros((tenants, params.rows, params.buckets),
                                 jnp.dtype(count_dtype)),
                n=jnp.zeros((tenants,), jnp.int32),
            )
        if bank.counts.shape[0] != tenants:
            raise ValueError(
                f"bank holds {bank.counts.shape[0]} sketches for "
                f"{tenants} tenants"
            )
        self.count_dtype = bank.counts.dtype
        self._counts = bank.counts
        self._n = bank.n
        self._ingest_q: Deque[_PendingIngest] = deque()
        self._query_q: Deque[_PendingQuery] = deque()
        self.ticks = 0
        self.rows_ingested = 0
        self.points_served = 0
        self._tick_full, self._tick_ingest, self._tick_query = \
            self._build_ticks()

    # -- request plumbing ---------------------------------------------------

    def submit(self, req: Union[IngestRequest, QueryRequest]) -> None:
        if not 0 <= req.tenant < self.tenants:
            raise ValueError(f"tenant {req.tenant} out of range "
                             f"[0, {self.tenants})")
        if isinstance(req, IngestRequest):
            z = np.asarray(req.z, np.float32)
            if z.ndim != 2 or z.shape[1] != self.ingest_dim:
                raise ValueError(
                    f"ingest rows must be (rows, {self.ingest_dim}); got "
                    f"{z.shape}"
                )
            self._ingest_q.append(_PendingIngest(dataclasses.replace(req, z=z)))
        elif isinstance(req, QueryRequest):
            th = np.asarray(req.thetas, np.float32)
            if th.ndim != 2 or th.shape[1] != self.dim:
                raise ValueError(f"query thetas must be (q, {self.dim}); "
                                 f"got {th.shape}")
            self._query_q.append(_PendingQuery(
                dataclasses.replace(req, thetas=th),
                out=np.zeros((th.shape[0],), np.float32),
            ))
        else:
            raise TypeError(f"unknown request type {type(req).__name__}")

    def submit_many(self, reqs: Sequence[Union[IngestRequest, QueryRequest]]
                    ) -> None:
        for r in reqs:
            self.submit(r)

    @property
    def pending(self) -> int:
        return len(self._ingest_q) + len(self._query_q)

    @property
    def bank(self) -> sketch_lib.SketchBank:
        """The live counter bank (device arrays; post-last-tick state)."""
        return sketch_lib.SketchBank(counts=self._counts, n=self._n)

    def sketch_of(self, tenant: int) -> sketch_lib.Sketch:
        """Tenant ``tenant``'s sketch as a standalone view."""
        return self.bank.select(tenant)

    @property
    def trace_count(self) -> int:
        """Total traces across the three tick programs (jit-stability: this
        must stay <= 3 for any request mix over the gateway's lifetime)."""
        return sum(f._cache_size() for f in
                   (self._tick_full, self._tick_ingest, self._tick_query))

    # -- the fused tick -----------------------------------------------------

    def _build_ticks(self):
        """Build the three fixed tick programs (full / ingest / query).

        Each is its own jitted program over the same padded shapes — the
        tick picks one by which halves carry traffic, so a read-heavy tick
        never executes an all-masked insert (on these shapes the empty
        paired histogram costs several times the fused query itself).
        """
        w = self.w
        paired = self.paired
        mode = self.mode
        dtype = self.count_dtype
        narrow = jnp.dtype(dtype).itemsize < 4
        s, dim, in_dim = self.tenants, self.dim, self.ingest_dim
        i_cap, q_cap = self.ingest_slots, self.query_slots

        def ingest_half(counts, n, zbuf, zmask):
            # ONE fused banked insert over the (S, I, dim) stack; widen ->
            # add -> saturate keeps narrow counters safe (DESIGN.md §6).
            if paired:
                tile = ops.paired_hash_histogram_banked(zbuf, w, zmask,
                                                        mode=mode)
            else:
                tile = ops.hash_histogram_banked(zbuf, w, zmask, mode=mode)
            wide = counts.astype(jnp.int32) if narrow else counts
            wide = wide + tile
            new_counts = (sketch_lib.saturating_cast(wide, dtype)
                          if narrow else wide)
            return new_counts, n + jnp.sum(zmask, axis=1).astype(jnp.int32)

        def query_half(counts, n, qbuf, qmask):
            # ONE banked call; tenant-major slots route row i to table
            # i // Q (the member-major contract, member_map = arange(S)).
            idx = fleet.member_point_idx(
                jnp.arange(counts.shape[0], dtype=jnp.int32), qbuf.shape[0]
            )
            est = ops.query_theta_with_weights(
                sketch_lib.SketchBank(counts=counts, n=n),
                w, qbuf, paired=paired, mode=mode, sketch_idx=idx,
            )
            return jnp.where(qmask > 0, est, 0.0)

        def tick_full(counts, n, zbuf, zmask, qbuf, qmask):
            counts, n = ingest_half(counts, n, zbuf, zmask)
            return counts, n, query_half(counts, n, qbuf, qmask)

        def tick_ingest(counts, n, zbuf, zmask):
            return ingest_half(counts, n, zbuf, zmask)

        def tick_query(counts, n, qbuf, qmask):
            return query_half(counts, n, qbuf, qmask)

        if self.mesh is None:
            # Meshless fast path: ONE fused host->device transfer per tick.
            # The flat buffer is [zbuf | zmask | qbuf | qmask] (the suffix a
            # variant doesn't need is simply not shipped); slicing happens
            # inside the compiled program.
            z_end, zm_end = s * i_cap * in_dim, s * i_cap * (in_dim + 1)

            def unpack_ingest(flat):
                return (flat[:z_end].reshape(s, i_cap, in_dim),
                        flat[z_end:zm_end].reshape(s, i_cap))

            def unpack_query(flat, off):
                q_end = off + s * q_cap * dim
                return (flat[off:q_end].reshape(s * q_cap, dim),
                        flat[q_end:q_end + s * q_cap])

            return (
                jax.jit(lambda counts, n, flat: tick_full(
                    counts, n, *unpack_ingest(flat),
                    *unpack_query(flat, zm_end))),
                jax.jit(lambda counts, n, flat: tick_ingest(
                    counts, n, *unpack_ingest(flat))),
                jax.jit(lambda counts, n, flat: tick_query(
                    counts, n, *unpack_query(flat, 0))),
            )

        from repro import compat
        from repro.sharding import specs as sharding_specs

        bank_spec, _ = sharding_specs.gateway_specs(self.axis)
        sharding_specs.check_bank_divisible(self.tenants, self.mesh,
                                            self.axis)

        def shard(fn, n_in, n_out):
            return jax.jit(compat.shard_map(
                fn, mesh=self.mesh,
                in_specs=(bank_spec,) * n_in,
                out_specs=(bank_spec,) * n_out if n_out > 1 else bank_spec,
            ))

        return (shard(tick_full, 6, 3), shard(tick_ingest, 4, 2),
                shard(tick_query, 4, 1))

    def _pack_ingest(self):
        s, i_cap, dim = self.tenants, self.ingest_slots, self.ingest_dim
        zbuf = np.zeros((s, i_cap, dim), np.float32)
        zmask = np.zeros((s, i_cap), np.float32)
        fill = [0] * s
        taken = 0
        for st in self._ingest_q:
            t = st.req.tenant
            take = min(i_cap - fill[t], st.req.z.shape[0] - st.cursor)
            if take <= 0:
                continue
            zbuf[t, fill[t]:fill[t] + take] = st.req.z[
                st.cursor:st.cursor + take]
            zmask[t, fill[t]:fill[t] + take] = 1.0
            st.cursor += take
            fill[t] += take
            taken += take
        self._ingest_q = deque(
            st for st in self._ingest_q if st.cursor < st.req.z.shape[0]
        )
        return zbuf, zmask, taken

    def _pack_queries(self):
        s, q_cap, dim = self.tenants, self.query_slots, self.dim
        qbuf = np.zeros((s, q_cap, dim), np.float32)
        qmask = np.zeros((s, q_cap), np.float32)
        fill = [0] * s
        placements = []  # (pending, req_offset, tenant, slot_offset, count)
        for st in self._query_q:
            t = st.req.tenant
            take = min(q_cap - fill[t], st.req.thetas.shape[0] - st.cursor)
            if take <= 0:
                continue
            qbuf[t, fill[t]:fill[t] + take] = st.req.thetas[
                st.cursor:st.cursor + take]
            qmask[t, fill[t]:fill[t] + take] = 1.0
            placements.append((st, st.cursor, t, fill[t], take))
            st.cursor += take
            fill[t] += take
        return qbuf, qmask, placements

    def tick(self) -> TickReport:
        """Run one engine tick: fused banked ingest, then fused banked query.

        Dispatches one of the three fixed programs by which halves carry
        traffic; an idle tick is a host-side no-op. Queries packed into a
        mixed tick read the post-ingest counters (read-your-writes).
        """
        if not self._ingest_q and not self._query_q:
            self.ticks += 1  # idle tick: nothing to pack, nothing to run
            return TickReport(tick=self.ticks, results=[], rows_ingested=0,
                              points_served=0)
        zbuf, zmask, rows = self._pack_ingest()
        qbuf, qmask, placements = self._pack_queries()
        do_ingest, do_query = rows > 0, bool(placements)
        est = None
        if self.mesh is None:
            if do_ingest and do_query:
                flat = np.concatenate([zbuf.ravel(), zmask.ravel(),
                                       qbuf.ravel(), qmask.ravel()])
                self._counts, self._n, est = self._tick_full(
                    self._counts, self._n, flat)
            elif do_ingest:
                flat = np.concatenate([zbuf.ravel(), zmask.ravel()])
                self._counts, self._n = self._tick_ingest(
                    self._counts, self._n, flat)
            elif do_query:
                flat = np.concatenate([qbuf.ravel(), qmask.ravel()])
                est = self._tick_query(self._counts, self._n, flat)
        else:
            zargs = (jnp.asarray(zbuf), jnp.asarray(zmask))
            qargs = (jnp.asarray(qbuf.reshape(-1, self.dim)),
                     jnp.asarray(qmask.reshape(-1)))
            if do_ingest and do_query:
                self._counts, self._n, est = self._tick_full(
                    self._counts, self._n, *zargs, *qargs)
            elif do_ingest:
                self._counts, self._n = self._tick_ingest(
                    self._counts, self._n, *zargs)
            elif do_query:
                est = self._tick_query(self._counts, self._n, *qargs)
        served = 0
        results: List[QueryResult] = []
        if do_query:
            losses = np.asarray(est).reshape(self.tenants, self.query_slots)
            for st, req_off, t, slot_off, take in placements:
                st.out[req_off:req_off + take] = \
                    losses[t, slot_off:slot_off + take]
                served += take
        # Completion sweep runs even on ingest-only ticks: a zero-row query
        # request has no rows to place but must still complete and report.
        remaining: Deque[_PendingQuery] = deque()
        for st in self._query_q:
            if st.cursor == st.req.thetas.shape[0]:
                results.append(QueryResult(st.req.rid, st.req.tenant, st.out))
            else:
                remaining.append(st)
        self._query_q = remaining
        self.ticks += 1
        self.rows_ingested += rows
        self.points_served += served
        return TickReport(tick=self.ticks, results=results,
                          rows_ingested=rows, points_served=served)

    def run_until_idle(self, max_ticks: int = 10_000) -> List[QueryResult]:
        """Tick until every pending request is served; returns all results."""
        out: List[QueryResult] = []
        while self.pending and max_ticks > 0:
            out.extend(self.tick().results)
            max_ticks -= 1
        if self.pending:
            raise RuntimeError(f"{self.pending} requests still pending "
                               f"after the tick budget")
        return out
