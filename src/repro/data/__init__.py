from repro.data import datasets  # noqa: F401
