"""Synthetic datasets for the paper's experiments.

The paper evaluates on three UCI tables (airfoil N=1.4k d=9, autos N=159
d=26, parkinsons N=5.8k d=21). Those files are not bundled offline, so we
generate synthetic regression problems matched in (N, d), noise level and
conditioning — see DESIGN.md §7. The benchmark claims verified are relative
(STORM vs baselines across memory budgets), which survive the substitution.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    d: int
    noise: float
    condition: float  # ratio of largest/smallest feature covariance eigenvalue


UCI_MATCHED = (
    DatasetSpec("airfoil", n=1400, d=9, noise=0.3, condition=30.0),
    DatasetSpec("autos", n=159, d=26, noise=0.2, condition=100.0),
    DatasetSpec("parkinsons", n=5800, d=21, noise=0.4, condition=50.0),
)


def make_regression(
    key: Array, n: int, d: int, noise: float = 0.1, condition: float = 10.0
) -> Tuple[Array, Array, Array]:
    """Linear-Gaussian regression with controlled covariance conditioning.

    Returns ``(x, y, theta_true)``; ``y = x @ theta_true + noise * eps``.
    """
    k_x, k_t, k_e, k_rot = jax.random.split(key, 4)
    eigs = jnp.logspace(0.0, jnp.log10(condition), d)
    eigs = eigs / jnp.mean(eigs)
    rot, _ = jnp.linalg.qr(jax.random.normal(k_rot, (d, d)))
    x = jax.random.normal(k_x, (n, d)) * jnp.sqrt(eigs)
    x = x @ rot.T
    theta = jax.random.normal(k_t, (d,))
    y = x @ theta + noise * jax.random.normal(k_e, (n,))
    return x, y, theta


def make_uci_matched(key: Array, spec: DatasetSpec) -> Tuple[Array, Array, Array]:
    return make_regression(key, spec.n, spec.d, spec.noise, spec.condition)


def make_2d_regression(key: Array, n: int = 2000, noise: float = 0.1):
    """The paper's Fig. 5 qualitative 2D regression dataset."""
    k_x, k_e = jax.random.split(key)
    x = jax.random.uniform(k_x, (n, 1), minval=-1.0, maxval=1.0)
    theta = jnp.asarray([0.7])
    y = x @ theta + noise * jax.random.normal(k_e, (n,))
    return jnp.concatenate([x], axis=-1), y, theta


def make_classification(
    key: Array, n: int = 2000, d: int = 2, margin: float = 0.5
) -> Tuple[Array, Array, Array]:
    """Two linearly separable Gaussian blobs; labels in {-1, +1}."""
    k_x, k_t = jax.random.split(key)
    theta = jax.random.normal(k_t, (d,))
    theta = theta / jnp.linalg.norm(theta)
    x = jax.random.normal(k_x, (n, d))
    y = jnp.sign(x @ theta)
    x = x + margin * y[:, None] * theta  # push blobs apart
    return x, y, theta


def stream_batches(x: Array, y: Array, batch: int):
    """Host-side streaming iterator (one pass, no shuffling — edge order)."""
    n = x.shape[0]
    for i in range(0, n, batch):
        yield x[i : i + batch], y[i : i + batch]
