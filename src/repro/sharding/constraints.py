"""Activation-sharding hints.

``hint(x, name)`` applies ``jax.lax.with_sharding_constraint`` when the
launcher has installed a rule for ``name`` — a no-op otherwise (CPU smoke
tests never see a mesh). GSPMD propagates well from params + inputs alone for
most graphs; these named hooks are the handles the perf pass (§Perf) uses to
pin activation layouts where the default propagation picks badly.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_state = threading.local()


def _rules() -> Dict[str, PartitionSpec]:
    return getattr(_state, "rules", {})


@contextlib.contextmanager
def activation_rules(rules: Optional[Dict[str, PartitionSpec]]):
    """Install named activation sharding rules for the enclosed trace."""
    prev = _rules()
    _state.rules = dict(rules or {})
    try:
        yield
    finally:
        _state.rules = prev


def hint(x, name: str):
    rules = _rules()
    spec = rules.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
