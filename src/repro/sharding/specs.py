"""Named-sharding rules: params, optimizer state, inputs, decode caches.

Strategy (DESIGN.md §5):
  * **TP** (Megatron column->row) over the ``model`` axis for every matmul
    pair; GQA K/V projections shard only when ``num_kv_heads`` divides the
    axis, else they replicate (they are small).
  * **FSDP/ZeRO** over ``(pod, data)`` on one non-TP dim of every large
    param — weights are all-gathered per layer inside the scan; optimizer
    moments/master follow the same specs, which is ZeRO-1 for free.
  * **MoE**: expert dim sharded over ``model`` when divisible (true EP —
    phi3.5's 16 experts), otherwise TP inside each expert (mixtral's 8).
  * **Decode caches**: batch over ``data``; KV-sequence over ``model``
    (flash-decoding style — softmax stats psum instead of logit gathers);
    long_500k (batch=1) shards sequence over data too.

Every sharded dim is divisibility-checked against the actual leaf shape, so
the same rules serve 1-device CPU tests and the 512-chip production mesh.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

FSDP_MIN_SIZE = 1 << 20  # don't bother FSDP-sharding params under 1M elements


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (fsdp_axes, tp_axis)."""
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    return fsdp, tp


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


class SpecBuilder:
    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.fsdp, self.tp = mesh_axes(mesh)
        self.tp_size = _axis_size(mesh, self.tp)
        self.fsdp_size = _axis_size(mesh, self.fsdp)

    def _tp_if(self, dim: int) -> Optional[str]:
        return self.tp if self.tp and dim % self.tp_size == 0 else None

    def _fsdp_if(self, dim: int, numel: int):
        if not self.fsdp or numel < FSDP_MIN_SIZE:
            return None
        return self.fsdp if dim % self.fsdp_size == 0 else None

    def matmul2d(self, shape, stacked: bool, tp_dim: int):
        """Spec for a (possibly layer-stacked) 2D matmul weight.

        tp_dim: which logical dim (0/1 of the 2D part) carries TP.
        FSDP goes on the other dim when it divides.
        """
        off = 1 if stacked else 0
        d0, d1 = shape[off], shape[off + 1]
        numel = int(np.prod(shape))
        spec = [None] * len(shape)
        dims = [d0, d1]
        tp_axis = self._tp_if(dims[tp_dim])
        if tp_axis:
            spec[off + tp_dim] = tp_axis
        other = 1 - tp_dim
        spec[off + other] = self._fsdp_if(dims[other], numel)
        return P(*spec)

    def replicated_fsdp(self, shape, stacked: bool, dim: int = 0):
        """No TP; FSDP on one dim if large enough."""
        off = 1 if stacked else 0
        numel = int(np.prod(shape))
        spec = [None] * len(shape)
        spec[off + dim] = self._fsdp_if(shape[off + dim], numel)
        return P(*spec)

    def moe3d(self, shape, stacked: bool, tp_dim_in_expert: int):
        """(L?, E, d0, d1): EP over experts when divisible, else TP in-expert."""
        off = 1 if stacked else 0
        e = shape[off]
        numel = int(np.prod(shape))
        spec = [None] * len(shape)
        if self.tp and e % self.tp_size == 0:
            spec[off] = self.tp  # expert parallelism
            # FSDP the first matmul dim if it divides
            spec[off + 1] = self._fsdp_if(shape[off + 1], numel)
        else:
            spec[off + 1 + tp_dim_in_expert] = self._tp_if(
                shape[off + 1 + tp_dim_in_expert]
            )
            other = 1 - tp_dim_in_expert
            spec[off + 1 + other] = self._fsdp_if(shape[off + 1 + other], numel)
        return P(*spec)


# name -> (handler, kwargs); matched against the last path component(s)
def param_spec(path_str: str, leaf, builder: SpecBuilder) -> P:
    shape = leaf.shape
    stacked = path_str.startswith("['blocks']")
    name = path_str.split("'")[-2]  # last quoted key

    if leaf.ndim - (1 if stacked else 0) <= 1:
        # norms, biases, gate scalars: replicate (except wide out_norms)
        if name == "out_norm" and shape[-1] % builder.tp_size == 0 and builder.tp:
            return P(*([None] * (leaf.ndim - 1) + [builder.tp]))
        return P(*([None] * leaf.ndim))

    if name == "embed":
        # vocab over TP; feature over FSDP when divisible
        spec = [builder._tp_if(shape[0]), builder._fsdp_if(shape[1], leaf.size)]
        return P(*spec)
    if name == "unembed":
        return P(builder._fsdp_if(shape[0], leaf.size), builder._tp_if(shape[1]))

    in_moe = "['moe']" in path_str
    if in_moe:
        if name == "router":
            return builder.replicated_fsdp(shape, stacked, dim=0)
        if name in ("gate", "up"):
            return builder.moe3d(shape, stacked, tp_dim_in_expert=1)
        if name == "down":
            return builder.moe3d(shape, stacked, tp_dim_in_expert=0)

    if name in ("wq", "wk", "wv"):
        # column-parallel; K/V replicate when kv-heads don't divide TP
        off = 1 if stacked else 0
        out_dim = shape[off + 1]
        if name in ("wk", "wv"):
            kv = builder.cfg.num_kv_heads
            if builder.tp and kv % builder.tp_size != 0:
                return builder.replicated_fsdp(shape, stacked, dim=0)
        return builder.matmul2d(shape, stacked, tp_dim=1)
    if name in ("wo", "down", "wd"):
        return builder.matmul2d(shape, stacked, tp_dim=0)
    if name in ("gate", "up", "wo_gate", "w_x", "w_z"):
        return builder.matmul2d(shape, stacked, tp_dim=1)
    if name in ("w_bc", "w_dt", "w_if", "router"):
        return builder.replicated_fsdp(shape, stacked, dim=0)
    if name in ("conv_x_w", "conv_bc_w"):
        off = 1 if stacked else 0
        spec = [None] * leaf.ndim
        spec[off + 1] = builder._tp_if(shape[off + 1]) if name == "conv_x_w" else None
        return P(*spec)
    # default: replicate small, FSDP large
    return builder.replicated_fsdp(shape, stacked, dim=0)


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    b = SpecBuilder(mesh, cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(jax.tree_util.keystr(path), leaf, b),
        params,
    )


def opt_state_specs(opt_state: Any, pspecs: Any) -> Any:
    """Moments/master mirror param specs; scalars replicate."""
    import jax.tree_util as jtu

    def like_params(subtree):
        return jtu.tree_map(lambda _, s: s, subtree, pspecs)

    from repro.train.optimizer import AdamWState

    return AdamWState(
        step=P(),
        mu=like_params(opt_state.mu),
        nu=like_params(opt_state.nu),
        master=None if opt_state.master is None else like_params(opt_state.master),
    )


# ---------------------------------------------------------------------------
# Fleet-vectorized optimization (DESIGN.md §8)
# ---------------------------------------------------------------------------


def fleet_specs(axis: str = "fleet") -> Tuple[P, P]:
    """PartitionSpecs for fleet training against one replicated sketch.

    Single owner of the fleet-sharding convention used by
    ``core.distributed.fleet_fit``: every per-member array (iterates ``(F, d)``,
    PRNG keys ``(F, 2)``, σ/lr ladders ``(F,)``, loss traces ``(F, steps)``)
    shards its LEADING fleet axis over ``axis``; the sketch counters, hash
    params, and scalars replicate. Counters are read-only during optimization,
    so this layout needs zero per-step communication.

    Returns:
      ``(fleet, replicated)`` PartitionSpecs.
    """
    return P(axis), P()


def check_fleet_divisible(f: int, mesh: Mesh, axis: str) -> None:
    """Fail fast when the fleet cannot split evenly over the mesh axis."""
    size = mesh.shape[axis]
    if f % size:
        raise ValueError(
            f"fleet size {f} not divisible by mesh axis {axis!r} ({size} "
            f"devices); pad the fleet or choose F as a multiple"
        )


def bank_specs(axis: str = "bank") -> Tuple[P, P]:
    """PartitionSpecs for banked fleet training (DESIGN.md §9).

    Single owner of the bank-sharding convention used by
    ``core.distributed.fleet_fit_banked``: the gateway's tenants split over
    ``axis`` — the ``(S, R, B)`` counter bank and per-sketch counts ``(S,)``
    shard their LEADING bank axis, and every per-member array (member-major
    ``(S*F, ...)`` iterates, keys, σ/lr ladders, traces) shards its leading
    axis over the SAME mesh axis, so each device holds its tenants' counter
    tables together with exactly those tenants' fleet members. Hash params
    and scalars replicate. Counters are read-only during optimization and
    members never query another device's tenants, so the layout needs zero
    per-step communication — the bank axis batches exactly like the fleet
    axis (``fleet_specs``), only the counters shard instead of replicating.

    Returns:
      ``(bank, replicated)`` PartitionSpecs; ``bank`` serves both the
      counter stack and the member-major arrays.
    """
    return P(axis), P()


def gateway_specs(axis: str = "bank") -> Tuple[P, P]:
    """PartitionSpecs for the serving gateway's fused tick (DESIGN.md §10).

    The gateway tick is the bank layout (:func:`bank_specs`) applied to
    *traffic* instead of fleet members: the ``(S, R, B)`` counter bank and
    ``(S,)`` insert counts shard their leading tenant axis, and every
    per-tick buffer — the ``(S, I, dim)`` ingest stack, its ``(S, I)`` mask,
    and the tenant-major ``(S*Q, dim)`` query block with its ``(S*Q,)`` mask
    — shards the SAME axis, so each device ingests and answers exactly its
    own tenants with zero per-tick communication. Hash params and scalars
    replicate.

    Returns:
      ``(bank, replicated)`` PartitionSpecs; ``bank`` serves the counter
      stack and every tick buffer.
    """
    return bank_specs(axis)


def gateway_input_specs(axis: str = "bank") -> Tuple[P, P, P, P]:
    """Per-tick host-buffer specs ``(zbuf, zmask, qbuf, qmask)`` for the
    gateway's sharded dispatch (DESIGN.md §11).

    All four shard their LEADING axis over ``axis``: the ``(S, I, dim)``
    ingest stack and ``(S, I)`` mask split per tenant, and the tenant-major
    ``(S*Q, dim)`` query block and ``(S*Q,)`` mask split in whole-tenant
    runs (S divides the mesh axis, so S*Q does too). The double-buffered
    tick ``device_put``s each freshly-packed buffer with these shardings
    BEFORE dispatch, which keeps tick t+1's h2d transfer off tick t's
    critical path and preserves the no-aliasing overlap invariant: every
    in-flight tick owns its own committed input arrays, so overlapping
    dispatches can never read a buffer a later pack is writing.
    """
    bank, _ = bank_specs(axis)
    return (bank, bank, bank, bank)


def check_bank_divisible(s: int, mesh: Mesh, axis: str) -> None:
    """Fail fast when the bank cannot split evenly over the mesh axis."""
    size = mesh.shape[axis]
    if s % size:
        raise ValueError(
            f"bank size {s} not divisible by mesh axis {axis!r} ({size} "
            f"devices); pad the bank or choose S as a multiple"
        )


def tenant_placement(tenants: int, mesh: Mesh, axis: str = "bank"
                     ) -> np.ndarray:
    """Tenant -> shard map induced by the ``P(axis)`` leading-axis layout.

    The bank/gateway convention (:func:`bank_specs`, :func:`gateway_specs`)
    shards the leading tenant axis in contiguous equal blocks, so slot
    (= tenant, pre-tiering) ``i`` lives on shard ``i // (S / n_shards)``.
    This function is the single owner of that arithmetic — the tiered
    gateway composes it with its tenant->slot map to answer "which device
    holds tenant t right now", and :func:`rebalance_placement` produces
    permutations that keep the same contiguous layout while balancing load.

    Returns:
      ``(tenants,)`` int32 — shard index per tenant/slot.
    """
    check_bank_divisible(tenants, mesh, axis)
    shards = mesh.shape[axis]
    return np.repeat(np.arange(shards, dtype=np.int32), tenants // shards)


def rebalance_placement(loads, num_shards: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Load-balance tenants over equal-capacity shards, staying contiguous.

    Capacity-bounded LPT greedy: tenants in descending load order each go
    to the least-loaded shard that still has a free slot (every shard holds
    exactly ``T / num_shards`` tenants — the ``P(axis)`` layout is
    equal-block by construction, so capacity is not a knob). The output is
    a slot PERMUTATION: placing tenant ``slot_tenant[i]`` at bank slot
    ``i`` makes the standard contiguous sharding realize the balanced
    assignment — no new PartitionSpec machinery, just reordered slots.

    Args:
      loads: ``(T,)`` per-tenant load (rows/points per tick, bytes — any
        additive cost).
      num_shards: shard count; must divide ``T``.

    Returns:
      ``(slot_tenant, shard_of)``: ``slot_tenant[i]`` is the tenant to
      place at slot ``i`` (a permutation of ``arange(T)``), and
      ``shard_of[t]`` is tenant ``t``'s shard under that placement.
    """
    loads = np.asarray(loads, np.float64)
    t = loads.shape[0]
    if t % num_shards:
        raise ValueError(
            f"{t} tenants not divisible by {num_shards} shards; pad the "
            f"bank or choose T as a multiple"
        )
    cap = t // num_shards
    members: list = [[] for _ in range(num_shards)]
    totals = np.zeros(num_shards)
    for tenant in np.argsort(-loads, kind="stable"):
        open_shards = [s for s in range(num_shards) if len(members[s]) < cap]
        best = min(open_shards, key=lambda s: (totals[s], s))
        members[best].append(int(tenant))
        totals[best] += loads[tenant]
    slot_tenant = np.concatenate(
        [np.sort(np.asarray(m, np.int32)) for m in members])
    shard_of = np.empty((t,), np.int32)
    for shard, m in enumerate(members):
        shard_of[np.asarray(m, np.int32)] = shard
    return slot_tenant, shard_of


# ---------------------------------------------------------------------------
# Inputs / activations / caches
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Token/label/embeds batches: shard dim0 (batch) over DP axes."""
    dp = dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % max(dp_size, 1) == 0 and dp:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(spec, batch)


def decode_state_specs(state: Any, cfg: ModelConfig, mesh: Mesh,
                       batch_size: int) -> Any:
    """Cache sharding. Leaves are stacked (cycles, B, ...).

    * KV caches (cycles, B, T, KH, hd): B over DP when divisible; KV heads
      over TP when divisible, else T (sequence) over TP (flash-decoding);
      for B == 1 (long_500k) the sequence also takes the DP axes.
    * Recurrent states (cycles, B, H, ...): B over DP; heads over TP when
      divisible, else the wider state dim.
    """
    b = SpecBuilder(mesh, cfg)
    dp = dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)
    batch_ok = dp and batch_size % dp_size == 0

    def spec(leaf):
        shape = leaf.shape
        spec_l = [None] * leaf.ndim
        if leaf.ndim >= 2 and batch_ok:
            spec_l[1] = dp
        if leaf.ndim == 5:  # KV cache (cycles, B, KH, T, hd)
            kh, t = shape[2], shape[3]
            if b.tp and kh % b.tp_size == 0:
                spec_l[2] = b.tp
            elif b.tp and t % b.tp_size == 0:
                spec_l[3] = b.tp
            if not batch_ok and dp and t % (dp_size * b.tp_size) == 0 and \
                    spec_l[3] == b.tp:
                spec_l[3] = tuple(dp) + (b.tp,)
            elif not batch_ok and dp and spec_l[3] is None and \
                    t % dp_size == 0:
                spec_l[3] = dp
        elif leaf.ndim >= 3:  # recurrent states (cycles, B, H, ...)
            h = shape[2]
            if b.tp and h % b.tp_size == 0:
                spec_l[2] = b.tp
            elif b.tp and leaf.ndim >= 4 and shape[3] % b.tp_size == 0:
                spec_l[3] = b.tp
        return P(*spec_l)

    return jax.tree.map(spec, state)


def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def activation_hint_rules(cfg: ModelConfig, mesh: Mesh):
    """Named rules consumed by sharding.constraints.hint inside the model."""
    dp = dp_axes(mesh)
    if cfg.sequence_parallel and "model" in mesh.axis_names:
        # linear-recurrence archs: activations sequence-sharded over `model`
        return {"residual": P(dp, "model", None)}
    return {"residual": P(dp, None, None)}
