"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Optional mesh layout for 1000+-node scale (DESIGN.md §5): stages own
contiguous layer groups; microbatches stream through with a steady-state
rotation implemented as ``collective_permute`` along the ``pipe`` axis.
This module is deliberately model-agnostic — any ``fn(stage_params, x)``
block function works — and is demonstrated/tested on a toy 4-stage mesh
(``tests/test_pipeline.py``); the required production dry-run mesh stays
DP x TP per the assignment.

Schedule: with S stages and M microbatches, step t processes microbatch
``t - stage`` on each stage (bubble fraction (S-1)/(M+S-1), standard GPipe).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

Array = jax.Array


def pipeline_forward(
    fn: Callable[[jax.Array, Array], Array],
    stage_params: Array,      # leading dim == number of stages (sharded on pipe)
    x: Array,                 # (M, micro_batch, ...) microbatches
    mesh: Mesh,
    axis: str = "pipe",
) -> Array:
    """Run ``x`` through all pipeline stages. Returns the final activations.

    ``fn(params_for_stage, microbatch)`` applies one stage's layers.
    """
    n_stage = mesh.shape[axis]
    m = x.shape[0]
    assert m >= 1

    def stage_fn(params_local, x_local):
        # params_local: (1, ...) this stage's params; x_local: (M, mb, ...)
        # on stage 0 holds the microbatch stream, others start with zeros.
        stage = jax.lax.axis_index(axis)
        params_here = jax.tree.map(lambda p: p[0], params_local)
        steps = m + n_stage - 1

        def body(carry, t):
            buf, outputs = carry
            # which microbatch this stage sees at step t (GPipe diagonal)
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < m)
            # stage 0 injects from its local stream; others take the rotated buf
            inject = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(mb_idx, 0, m - 1), axis=0, keepdims=False
            )
            inp = jnp.where(stage == 0, inject, buf)
            out = fn(params_here, inp)
            out = jnp.where(active, out, jnp.zeros_like(out))
            # last stage records finished microbatches (masked update keeps
            # the varying-manual-axes type consistent under shard_map)
            updated = jax.lax.dynamic_update_index_in_dim(
                outputs, out, jnp.clip(mb_idx, 0, m - 1), axis=0
            )
            outputs = jnp.where(active & (stage == n_stage - 1), updated,
                                outputs)
            # rotate activations forward one stage
            perm = [(i, (i + 1) % n_stage) for i in range(n_stage)]
            buf = jax.lax.ppermute(out, axis, perm)
            return (buf, outputs), None

        buf0 = compat.pvary(jnp.zeros_like(x_local[0]), (axis,))
        outs0 = compat.pvary(jnp.zeros_like(x_local), (axis,))
        (_, outputs), _ = jax.lax.scan(body, (buf0, outs0),
                                       jnp.arange(steps))
        # only the last stage holds non-zero outputs; psum broadcasts them
        return jax.lax.psum(outputs, axis)

    fn_sharded = compat.shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    stage_params = jax.device_put(
        stage_params, NamedSharding(mesh, P(axis))
    )
    return fn_sharded(stage_params, x)
