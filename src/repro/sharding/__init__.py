from repro.sharding import constraints  # noqa: F401
