"""qwen3-32b [dense] — qk-norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
    attn_chunk=32,
    xent_chunk=32,
)
