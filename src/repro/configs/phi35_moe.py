"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    rope_theta=10000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    attn_chunk=32,
    xent_chunk=32,
)
