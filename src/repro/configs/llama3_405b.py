"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke",
    family="dense",
    num_layers=3,
    d_model=96,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    rope_theta=10000.0,
    attn_chunk=32,
    xent_chunk=32,
)
