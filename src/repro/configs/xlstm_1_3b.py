"""xlstm-1.3b [ssm] — mLSTM blocks. [arXiv:2405.04517; unverified]

Implemented with the sigmoid-gated mLSTM ("mLSTMsig", as in xLSTM-7B) in
chunked form; the 1.3B scale config is mLSTM-only (DESIGN.md §7).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    cycle=("mlstm",),
    ssm_heads=4,
    ssm_expand=2,
    rope_theta=0.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    num_layers=3,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=128,
    cycle=("mlstm",),
    ssm_heads=2,
    ssm_expand=2,
    rope_theta=0.0,
    attn_chunk=16,
    xent_chunk=32,
)
