"""qwen2-7b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    rope_theta=10000.0,
    attn_chunk=32,
    xent_chunk=32,
)
