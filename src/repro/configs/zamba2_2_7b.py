"""zamba2-2.7b [hybrid] — Mamba2 blocks + one shared attention+MLP block
invoked every 6th layer. [arXiv:2411.15242; hf]

The shared block's parameters are a single copy reused across all 9
invocations (per-invocation LoRA deltas from the reference model are omitted;
DESIGN.md §7). ssm_state=64, d_inner=2*d, headdim=64 -> 80 ssm heads.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    cycle=("mamba",) * 5 + ("shared_attn",),
    ssm_state_dim=64,
    ssm_heads=80,
    ssm_expand=2,
    rope_theta=10000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    num_layers=12,
    d_model=32,
    num_heads=4,
    num_kv_heads=4,
    head_dim=8,
    d_ff=64,
    vocab_size=128,
    cycle=("mamba",) * 5 + ("shared_attn",),
    ssm_state_dim=8,
    ssm_heads=4,
    ssm_expand=2,
    attn_chunk=16,
    xent_chunk=32,
)
