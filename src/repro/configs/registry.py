"""Architecture registry + assigned input shapes.

Every assigned architecture is selectable by id (``--arch <id>``); each id
maps to its exact published config and a reduced same-family smoke config.

Shapes (LM family, per the assignment):
  * train_4k:     seq 4,096 x global batch 256    -> train_step
  * prefill_32k:  seq 32,768 x global batch 32    -> prefill_step
  * decode_32k:   KV len 32,768 x global batch 128 -> serve_step (1 token)
  * long_500k:    KV len 524,288 x global batch 1  -> serve_step (1 token),
                  run only for sub-quadratic-decode architectures
                  (skip list + rationale in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs import (
    gemma3_1b,
    llama3_405b,
    llama32_vision_11b,
    mixtral_8x22b,
    musicgen_medium,
    phi35_moe,
    qwen2_7b,
    qwen3_32b,
    xlstm_1_3b,
    zamba2_2_7b,
)
from repro.models.config import ModelConfig

_MODULES = {
    "qwen2-7b": qwen2_7b,
    "gemma3-1b": gemma3_1b,
    "llama3-405b": llama3_405b,
    "qwen3-32b": qwen3_32b,
    "xlstm-1.3b": xlstm_1_3b,
    "zamba2-2.7b": zamba2_2_7b,
    "mixtral-8x22b": mixtral_8x22b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "musicgen-medium": musicgen_medium,
    "llama-3.2-vision-11b": llama32_vision_11b,
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Architectures with sub-quadratic decode state (DESIGN.md §4). All others
# skip long_500k (pure full attention — 500k dense-KV decode).
LONG_CONTEXT_ARCHS = frozenset(
    {"xlstm-1.3b", "zamba2-2.7b", "mixtral-8x22b", "gemma3-1b"}
)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return "pure full attention: 500k dense-KV decode is quadratic-history"
    return None
