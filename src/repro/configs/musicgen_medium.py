"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf]

The EnCodec frontend (4 codebooks, delay pattern) is a STUB per the brief:
``input_specs()`` provides precomputed frame embeddings (B, S, d); the loss
head predicts the 2048-entry codebook vocabulary.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    embeddings_provided=True,
    rope_theta=10000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    family="audio",
    num_layers=2,
    d_model=48,
    num_heads=4,
    num_kv_heads=4,
    head_dim=12,
    d_ff=96,
    vocab_size=128,
    embeddings_provided=True,
    attn_chunk=32,
    xent_chunk=32,
)
