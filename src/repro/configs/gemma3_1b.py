"""gemma3-1b [dense] — 5:1 local:global attention, 256k vocab, MQA (kv=1).

[hf:google/gemma-3-1b-pt; unverified]. 26 layers is not a multiple of 6, so
the 5:1 pattern is expressed as a 13-layer cycle (5L,1G,5L,1G,1L) x 2 —
globals at depths 5,11,18,24 vs the reference 5,11,17,23 (DESIGN.md §7).
"""

from repro.models.config import ModelConfig

_CYCLE = ("local_attn",) * 5 + ("attn",) + ("local_attn",) * 5 + ("attn",) + (
    "local_attn",
)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    cycle=_CYCLE,
    local_window=512,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke",
    family="dense",
    num_layers=13,
    d_model=48,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    cycle=_CYCLE,
    local_window=16,
    tie_embeddings=True,
    attn_chunk=16,
    xent_chunk=32,
)
