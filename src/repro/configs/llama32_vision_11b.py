"""llama-3.2-vision-11b [vlm] — text decoder with cross-attention image
layers every 5th layer. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB: ``input_specs()`` provides projected patch
embeddings (B, T_img, d) consumed by the cross-attention layers.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cycle=("attn",) * 4 + ("cross_attn",),
    cross_attn_tokens=4096,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-smoke",
    family="vlm",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    cycle=("attn",) * 4 + ("cross_attn",),
    cross_attn_tokens=64,
    attn_chunk=32,
    xent_chunk=32,
)
