"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat_policy="nothing",
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    num_experts=4,
    experts_per_token=2,
    sliding_window=32,
    attn_chunk=16,
    xent_chunk=32,
)
